//! Typed experiment schema. Every run of the system — CLI, benches,
//! integration tests, examples — is described by an [`ExperimentConfig`],
//! loadable from a TOML file or built from the named presets that mirror
//! the paper's experimental setups.

use anyhow::{anyhow, bail, Context, Result};

use super::toml::{self, Table, Value};
use crate::network::fault::{ChurnEntry, FaultPlanConfig, LinkFaultConfig};

/// Which compute backend executes the kernel algebra.
#[derive(Debug, Clone, PartialEq)]
pub enum RuntimeBackend {
    /// Pure-Rust kernel math (always available; also the oracle).
    Native,
    /// PJRT CPU client executing the AOT artifacts from `artifacts/`.
    Xla { artifacts_dir: String, variant: String },
}

/// Loss function of the online learner.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossKind {
    /// Hinge loss max(0, 1 - y f(x)) — classification.
    Hinge,
    /// Logistic loss ln(1 + exp(-y f(x))) — classification.
    Logistic,
    /// Squared loss 1/2 (f(x) - y)^2 — regression.
    Squared,
    /// eps-insensitive |f(x) - y|_eps — regression (PA-style).
    EpsInsensitive(f64),
}

/// Kernel function of the hypothesis space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelConfig {
    /// Plain linear models w^T x (the 2014 paper's setting).
    Linear,
    /// Gaussian RBF k(x, z) = exp(-gamma ||x - z||^2).
    Rbf { gamma: f64 },
    /// Random-Fourier-Features approximation of the RBF kernel with `dim`
    /// features — a *fixed-size* model (paper §4 future work; Rahimi &
    /// Recht 2007). Messages are constant-size like linear models.
    Rff { gamma: f64, dim: usize },
}

/// Model-compression scheme bounding the support-vector count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompressionConfig {
    /// Unbounded support set (model grows with T).
    None,
    /// Truncation [Kivinen et al. 2004]: drop the oldest SV beyond `tau`
    /// (its coefficient has decayed the most under (1 - eta*lambda) decay).
    Truncation { tau: usize },
    /// Projection [Orabona et al. 2009]: project a dropped SV onto the
    /// span of the survivors instead of discarding its contribution.
    Projection { tau: usize },
}

impl CompressionConfig {
    /// Budget tau if the scheme bounds the model size.
    pub fn budget(&self) -> Option<usize> {
        match self {
            CompressionConfig::None => None,
            CompressionConfig::Truncation { tau } | CompressionConfig::Projection { tau } => {
                Some(*tau)
            }
        }
    }
}

/// The online learning algorithm `A = (H, phi, l)` run at each node.
#[derive(Debug, Clone, PartialEq)]
pub struct LearnerConfig {
    /// Learning rate eta (update magnitude; Prop. 6's drift constant).
    pub eta: f64,
    /// Regularization lambda (coefficient decay (1 - eta*lambda) per step).
    pub lambda: f64,
    pub loss: LossKind,
    pub kernel: KernelConfig,
    pub compression: CompressionConfig,
    /// Passive-aggressive updates (loss-proportional with gamma = 1 /
    /// (||x||^2 + 1/(2C))) instead of plain SGD.
    pub passive_aggressive: bool,
}

/// Synchronization operator sigma of the distributed protocol.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ProtocolConfig {
    /// No communication at all — the m-isolated-learners extreme.
    NoSync,
    /// sigma_1: average every round.
    Continuous,
    /// sigma_b: average every `period` rounds.
    Periodic { period: usize },
    /// sigma_Delta: average only on local-condition violation (the paper's
    /// contribution). `check_period` > 1 enables the §4 mini-batch check
    /// that bounds peak communication.
    Dynamic { delta: f64, check_period: usize },
    /// sigma_{Delta_t} with the decaying threshold Delta_t = delta0 / sqrt(t)
    /// — the schedule the paper notes achieves consistency for static
    /// target distributions (Sec. 3, after Thm. 4).
    DynamicDecay { delta0: f64, check_period: usize },
    /// Serial oracle: all mT examples processed by one central learner.
    Serial,
}

impl ProtocolConfig {
    pub fn label(&self) -> String {
        match self {
            ProtocolConfig::NoSync => "nosync".into(),
            ProtocolConfig::Continuous => "continuous".into(),
            ProtocolConfig::Periodic { period } => format!("periodic(b={period})"),
            ProtocolConfig::Dynamic {
                delta,
                check_period,
            } => {
                if *check_period > 1 {
                    format!("dynamic(Δ={delta},b={check_period})")
                } else {
                    format!("dynamic(Δ={delta})")
                }
            }
            ProtocolConfig::DynamicDecay { delta0, .. } => {
                format!("dynamic-decay(Δ0={delta0})")
            }
            ProtocolConfig::Serial => "serial".into(),
        }
    }
}

/// Input stream configuration (all synthetic — see DESIGN.md §5).
#[derive(Debug, Clone, PartialEq)]
pub enum DataConfig {
    /// SUSY-like binary classification: 8 correlated "low-level" features
    /// per class + 10 derived nonlinear features; not linearly separable.
    Susy { noise: f64 },
    /// Stock nowcasting regression: latent market + sector factors,
    /// target = saturating nonlinear function of correlated lagged prices.
    Stock { stocks: usize, noise: f64 },
    /// Rotating-hyperplane drift benchmark (linear-friendly, drifting).
    Hyperplane { dim: usize, drift: f64 },
    /// Gaussian-mixture XOR-style classification (kernel-friendly).
    Mixture { dim: usize, separation: f64 },
}

impl DataConfig {
    /// Input dimensionality of the generated feature vectors.
    pub fn dim(&self) -> usize {
        match self {
            DataConfig::Susy { .. } => 18,
            DataConfig::Stock { stocks, .. } => *stocks,
            DataConfig::Hyperplane { dim, .. } => *dim,
            DataConfig::Mixture { dim, .. } => *dim,
        }
    }

    /// Whether targets are +-1 labels (true) or real values (false).
    pub fn is_classification(&self) -> bool {
        !matches!(self, DataConfig::Stock { .. })
    }
}

/// How `kdol cluster` wires the leader and its workers together.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportConfig {
    /// One OS process, worker threads on the in-process channel bus — the
    /// deterministic default, and the only transport that supports fault
    /// injection (seeded link state lives in sender-side memory).
    InProcess,
    /// This process is the leader: bind `addr` (e.g. `127.0.0.1:7070`)
    /// and accept every worker over TCP before the run starts.
    Listen { addr: String },
    /// This process is worker `worker`: connect to the leader at `addr`
    /// and run that learner's stream.
    Join { addr: String, worker: usize },
}

/// Network graph family of the leaderless gossip runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GossipTopology {
    /// Cycle: node i talks to i±1 (degree 2).
    Ring,
    /// a×b grid with wraparound, a the largest divisor of n with a² ≤ n
    /// (degree 4, or 3 when a = 2 — the up/down neighbor coincides).
    Torus,
    /// Seeded random k-regular graph (pairing model, resampled until
    /// simple and connected).
    Regular,
    /// Every pair adjacent — one diffusion round equals the leader's
    /// full-sync average (the parity pin).
    Complete,
}

impl GossipTopology {
    pub fn label(&self) -> &'static str {
        match self {
            GossipTopology::Ring => "ring",
            GossipTopology::Torus => "torus",
            GossipTopology::Regular => "regular",
            GossipTopology::Complete => "complete",
        }
    }

    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Option<GossipTopology> {
        match s {
            "ring" => Some(GossipTopology::Ring),
            "torus" => Some(GossipTopology::Torus),
            "regular" => Some(GossipTopology::Regular),
            "complete" => Some(GossipTopology::Complete),
            _ => None,
        }
    }
}

/// `[gossip]` — leaderless diffusion runtime (see `coordinator::gossip`):
/// every node exchanges fixed-size model frames with its graph neighbors
/// and combines them under Metropolis–Hastings weights. No leader exists;
/// `protocol`/`partial_sync`/`lockstep` do not apply.
#[derive(Debug, Clone, PartialEq)]
pub struct GossipConfig {
    pub topology: GossipTopology,
    /// Target degree k of the `regular` family (the other families fix
    /// their own degree).
    pub degree: usize,
    /// Exchange with neighbors every `period` rounds.
    pub period: usize,
    /// Seed of the topology's own `Pcg64` stream — graph generation is a
    /// pure function of (seed, n, degree).
    pub seed: u64,
}

/// A full experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Number of local learners m.
    pub learners: usize,
    /// Rounds T (each learner sees one example per round).
    pub rounds: usize,
    pub data: DataConfig,
    pub learner: LearnerConfig,
    pub protocol: ProtocolConfig,
    pub backend: RuntimeBackend,
    /// Record cumulative metrics every this many rounds (for the
    /// over-time curves of Fig 1b / Fig 2b).
    pub record_every: usize,
    /// Partial-synchronization refinement (the local-balancing scheme of
    /// [10] that Sec. 2 references): on violation, the coordinator first
    /// tries to rebalance a *subset* of learners around the violators —
    /// if the subset average satisfies `||avg_B - r||^2 <= Delta` the
    /// members adopt it and the shared reference stays valid, so the rest
    /// of the cluster neither hears about it nor transmits anything. Only
    /// when no subset resolves does it escalate to a full sync.
    pub partial_sync: bool,
    /// Thread count of the deterministic parallel kernel-algebra backend
    /// (`util::par`); 0 = auto (available parallelism). Results are
    /// bitwise identical at any setting — this is purely a throughput
    /// knob.
    pub threads: usize,
    /// Lockstep conformance mode of the threaded cluster runtime: workers
    /// pace protocol rounds with the leader over *uncounted* control
    /// messages (`RoundDone`/`Proceed`), so the cluster's trajectory —
    /// violation sets, balancing events, every protocol byte — is
    /// deterministic and must equal the engine's exactly. Costs one
    /// barrier per round; off (free-running workers) is the deployable
    /// default.
    pub lockstep: bool,
    /// Leader receive deadline per collection attempt (ms). Exceeding it
    /// triggers the bounded retry ladder; after `max_retries` the leader
    /// escalates (full sync) or quarantines the unresponsive worker.
    pub recv_timeout_ms: u64,
    /// Re-request attempts after the first deadline before escalating.
    pub max_retries: u32,
    /// Seeded fault-injection plan for the cluster bus (`None` = clean).
    /// Same seed ⇒ identical fault schedule, so chaos runs replay.
    pub faults: Option<FaultPlanConfig>,
    /// Planned worker membership windows (join/leave churn); empty = all
    /// workers play every round. Requires lockstep mode — the plan is
    /// round-synchronous and known to leader and workers alike.
    pub churn: Vec<ChurnEntry>,
    /// Closed-loop serving clients scoring the shared reference *while*
    /// the cluster trains (0 = no live serving tier). Requires an RBF
    /// kernel model — the tier serves SV expansions. See
    /// `coordinator::serving`.
    pub serve_clients: usize,
    /// Serving shards backing those clients (0 = one shard).
    pub serve_shards: usize,
    /// Cluster transport: in-process bus (default) or one side of a
    /// multi-process TCP cluster (`--listen` / `--join`).
    pub transport: TransportConfig,
    /// Leaderless gossip/diffusion runtime (`kdol gossip`); `None` = the
    /// coordinator-centric protocols above.
    pub gossip: Option<GossipConfig>,
}

impl ExperimentConfig {
    // ----- presets mirroring the paper's setups ---------------------------

    /// Fig 1 base geometry: SUSY-like, m = 4, 1000 instances per learner.
    fn fig1_base(name: &str) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            seed: 20190613,
            learners: 4,
            rounds: 1000,
            data: DataConfig::Susy { noise: 0.08 },
            learner: LearnerConfig {
                eta: 0.35,
                lambda: 1e-3,
                loss: LossKind::Hinge,
                kernel: KernelConfig::Rbf { gamma: 0.25 },
                compression: CompressionConfig::None,
                passive_aggressive: false,
            },
            protocol: ProtocolConfig::Continuous,
            backend: RuntimeBackend::Native,
            record_every: 10,
            partial_sync: false,
            threads: 0,
            lockstep: false,
            recv_timeout_ms: 60_000,
            max_retries: 2,
            faults: None,
            churn: Vec::new(),
            serve_clients: 0,
            serve_shards: 0,
            transport: TransportConfig::InProcess,
            gossip: None,
        }
    }

    pub fn fig1_linear(protocol: ProtocolConfig) -> ExperimentConfig {
        let mut c = Self::fig1_base(&format!("fig1-linear-{}", protocol.label()));
        c.learner.kernel = KernelConfig::Linear;
        c.learner.eta = 0.05;
        c.protocol = protocol;
        c
    }

    pub fn fig1_kernel(protocol: ProtocolConfig) -> ExperimentConfig {
        let mut c = Self::fig1_base(&format!("fig1-kernel-{}", protocol.label()));
        c.protocol = protocol;
        c
    }

    pub fn fig1_dynamic_kernel(delta: f64) -> ExperimentConfig {
        Self::fig1_kernel(ProtocolConfig::Dynamic {
            delta,
            check_period: 1,
        })
    }

    pub fn fig1_dynamic_kernel_compressed(delta: f64, tau: usize) -> ExperimentConfig {
        let mut c = Self::fig1_dynamic_kernel(delta);
        c.name = format!("fig1-kernel-trunc{tau}-dynamic(Δ={delta})");
        c.learner.compression = CompressionConfig::Truncation { tau };
        c
    }

    /// Fig 2 base geometry: stock nowcasting, m = 32, SGD, Gaussian kernel
    /// truncated to 50 SVs (paper's setting).
    fn fig2_base(name: &str) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            seed: 20190802,
            learners: 32,
            rounds: 4000,
            data: DataConfig::Stock {
                stocks: 32,
                noise: 0.02,
            },
            learner: LearnerConfig {
                eta: 0.5,
                lambda: 0.01,
                loss: LossKind::Squared,
                kernel: KernelConfig::Rbf { gamma: 0.5 },
                compression: CompressionConfig::Truncation { tau: 50 },
                passive_aggressive: false,
            },
            protocol: ProtocolConfig::Periodic { period: 1 },
            backend: RuntimeBackend::Native,
            record_every: 20,
            partial_sync: false,
            threads: 0,
            lockstep: false,
            recv_timeout_ms: 60_000,
            max_retries: 2,
            faults: None,
            churn: Vec::new(),
            serve_clients: 0,
            serve_shards: 0,
            transport: TransportConfig::InProcess,
            gossip: None,
        }
    }

    pub fn fig2_kernel(protocol: ProtocolConfig) -> ExperimentConfig {
        let mut c = Self::fig2_base(&format!("fig2-kernel-{}", protocol.label()));
        c.protocol = protocol;
        c
    }

    pub fn fig2_linear(protocol: ProtocolConfig) -> ExperimentConfig {
        let mut c = Self::fig2_base(&format!("fig2-linear-{}", protocol.label()));
        // Tuned like the paper's dynamic linear system (they used a large
        // eta = 1.0): the step is big enough that the linear model — which
        // cannot fit the nonlinear target — keeps moving and keeps
        // violating its local condition. That is exactly why the paper's
        // linear baseline both errs ~18x more *and* keeps communicating
        // while the dynamic kernel system quiesces. The eps-insensitive
        // loss bounds the subgradient so the large step stays finite.
        c.learner.kernel = KernelConfig::Linear;
        c.learner.eta = 0.3;
        c.learner.lambda = 0.02;
        c.learner.loss = LossKind::EpsInsensitive(0.01);
        c.learner.compression = CompressionConfig::None;
        c.protocol = protocol;
        c
    }

    /// Quickstart: small, fast, kernel + dynamic.
    pub fn quickstart() -> ExperimentConfig {
        let mut c = Self::fig1_dynamic_kernel_compressed(0.5, 32);
        c.name = "quickstart".into();
        c.learners = 2;
        c.rounds = 200;
        c
    }

    // ----- validation ------------------------------------------------------

    pub fn validate(&self) -> Result<()> {
        if self.learners == 0 {
            bail!("learners must be >= 1");
        }
        if self.rounds == 0 {
            bail!("rounds must be >= 1");
        }
        if self.record_every == 0 {
            bail!("record_every must be >= 1");
        }
        if self.threads > crate::util::par::MAX_THREADS {
            bail!(
                "threads must be <= {} (0 = auto)",
                crate::util::par::MAX_THREADS
            );
        }
        if !(self.learner.eta > 0.0) {
            bail!("eta must be > 0");
        }
        if self.learner.lambda < 0.0 {
            bail!("lambda must be >= 0");
        }
        match self.learner.kernel {
            KernelConfig::Rbf { gamma } if !(gamma >= 0.0) => bail!("gamma must be >= 0"),
            KernelConfig::Rff { gamma, dim } => {
                if !(gamma >= 0.0) {
                    bail!("gamma must be >= 0");
                }
                if dim == 0 {
                    bail!("rff feature dim must be >= 1");
                }
            }
            _ => {}
        }
        if let Some(tau) = self.learner.compression.budget() {
            if tau == 0 {
                bail!("compression budget tau must be >= 1");
            }
        }
        match self.protocol {
            ProtocolConfig::Periodic { period } if period == 0 => {
                bail!("periodic protocol needs period >= 1")
            }
            ProtocolConfig::Dynamic { delta, check_period } => {
                if !(delta >= 0.0) {
                    bail!("divergence threshold must be >= 0");
                }
                if check_period == 0 {
                    bail!("check_period must be >= 1");
                }
            }
            ProtocolConfig::DynamicDecay { delta0, check_period } => {
                if !(delta0 > 0.0) {
                    bail!("delta0 must be > 0");
                }
                if check_period == 0 {
                    bail!("check_period must be >= 1");
                }
            }
            _ => {}
        }
        if matches!(
            self.learner.kernel,
            KernelConfig::Linear | KernelConfig::Rff { .. }
        ) && self.learner.compression.budget().is_some()
        {
            bail!("compression only applies to support-vector models");
        }
        if self.recv_timeout_ms == 0 {
            bail!("recv_timeout_ms must be >= 1");
        }
        if self.serve_clients > 0 && !matches!(self.learner.kernel, KernelConfig::Rbf { .. }) {
            bail!("serve_clients requires an RBF kernel model (the serving tier serves SvModels)");
        }
        if let Some(f) = &self.faults {
            f.validate(self.learners).map_err(|e| anyhow!(e))?;
        }
        if !self.churn.is_empty() {
            if !self.lockstep {
                bail!("churn requires lockstep mode (the membership plan is round-synchronous)");
            }
            let mut seen = vec![false; self.learners];
            for c in &self.churn {
                if c.worker >= self.learners {
                    bail!(
                        "churn names worker {}, but the cluster has {}",
                        c.worker,
                        self.learners
                    );
                }
                if seen[c.worker] {
                    bail!("churn lists worker {} twice", c.worker);
                }
                seen[c.worker] = true;
                if c.join == 0 || c.join > c.leave {
                    bail!("churn window {c} must satisfy 1 <= join <= leave");
                }
                if c.leave > self.rounds as u64 {
                    bail!("churn window {c} ends after the last round {}", self.rounds);
                }
            }
        }
        match &self.transport {
            TransportConfig::InProcess => {}
            TransportConfig::Listen { addr } | TransportConfig::Join { addr, .. } => {
                if addr.is_empty() {
                    bail!("transport addr must be non-empty (e.g. 127.0.0.1:7070)");
                }
                if self.faults.is_some() {
                    // Seeded fault state lives in sender-side memory on the
                    // in-process bus; a socket backend cannot replay the
                    // same schedule deterministically.
                    bail!("fault injection is in-process only; drop [faults] or [transport]");
                }
                if let TransportConfig::Join { worker, .. } = &self.transport {
                    if *worker >= self.learners {
                        bail!(
                            "transport.worker is {}, but the cluster has {} learners",
                            worker,
                            self.learners
                        );
                    }
                }
            }
        }
        if let Some(g) = &self.gossip {
            if self.learners < 2 {
                bail!("gossip needs learners >= 2 (a 1-node graph has no edges)");
            }
            if g.period == 0 {
                bail!("gossip.period must be >= 1");
            }
            if matches!(self.learner.kernel, KernelConfig::Rbf { .. }) {
                bail!("gossip diffusion averages fixed-size models; use kernel = linear or rff");
            }
            match g.topology {
                GossipTopology::Regular => {
                    if g.degree == 0 || g.degree >= self.learners {
                        bail!(
                            "regular topology needs 1 <= degree < learners ({} vs {})",
                            g.degree,
                            self.learners
                        );
                    }
                    if self.learners * g.degree % 2 != 0 {
                        bail!("regular topology needs learners * degree even (handshake lemma)");
                    }
                }
                GossipTopology::Torus => {
                    let n = self.learners;
                    if n < 4 || !(2..n).any(|a| n % a == 0) {
                        bail!("torus topology needs a composite learner count >= 4");
                    }
                }
                GossipTopology::Ring | GossipTopology::Complete => {}
            }
            if self.lockstep {
                bail!("gossip has no leader to pace lockstep rounds");
            }
            if self.partial_sync {
                bail!("partial sync is a leader-protocol refinement; gossip has no leader");
            }
            if !self.churn.is_empty() {
                bail!("gossip does not support churn windows (leader-run membership plan)");
            }
            if self.serve_clients > 0 {
                bail!("the serving tier hangs off the leader runtime, not gossip");
            }
            if self.transport != TransportConfig::InProcess {
                bail!(
                    "gossip meshes are formed from CLI flags (--node-id/--listen/--peers), \
                     not [transport]"
                );
            }
        }
        match (&self.data, self.learner.loss) {
            (d, LossKind::Squared) | (d, LossKind::EpsInsensitive(_)) if d.is_classification() => {
                bail!("regression loss on a classification stream")
            }
            (d, LossKind::Hinge) | (d, LossKind::Logistic) if !d.is_classification() => {
                bail!("classification loss on a regression stream")
            }
            _ => Ok(()),
        }
    }

    /// Digest over everything leader and workers must agree on for a
    /// multi-process run; the TCP handshake refuses a mismatch before any
    /// protocol frame crosses the link. The transport section itself is
    /// normalized out — the leader listens while workers join, and that
    /// asymmetry is expected. FNV-1a over the canonical `Debug` rendering
    /// keeps this dependency-free and stable for any two processes of the
    /// same build.
    pub fn cluster_digest(&self) -> u64 {
        let mut canon = self.clone();
        canon.transport = TransportConfig::InProcess;
        let repr = format!("{canon:?}");
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in repr.bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    // ----- TOML loading ----------------------------------------------------

    /// Parse a config from TOML text.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let t = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_table(&t)
    }

    /// Load from a file path.
    pub fn from_path(path: &std::path::Path) -> Result<ExperimentConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&text)
    }

    fn from_table(t: &Table) -> Result<ExperimentConfig> {
        let mut cfg = match get_str(t, "preset") {
            Some("fig1") => Self::fig1_kernel(ProtocolConfig::Continuous),
            Some("fig2") => Self::fig2_kernel(ProtocolConfig::Periodic { period: 1 }),
            Some("quickstart") | None => Self::quickstart(),
            Some(other) => bail!("unknown preset `{other}`"),
        };
        if let Some(v) = get_str(t, "name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = get_int(t, "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_int(t, "learners") {
            cfg.learners = v as usize;
        }
        if let Some(v) = get_int(t, "rounds") {
            cfg.rounds = v as usize;
        }
        if let Some(v) = get_int(t, "record_every") {
            cfg.record_every = v as usize;
        }
        if let Some(v) = t.get("partial_sync").and_then(Value::as_bool) {
            cfg.partial_sync = v;
        }
        if let Some(v) = t.get("lockstep").and_then(Value::as_bool) {
            cfg.lockstep = v;
        }
        if let Some(d) = t.get("data").and_then(Value::as_table) {
            cfg.data = parse_data(d)?;
        }
        if let Some(l) = t.get("learner").and_then(Value::as_table) {
            cfg.learner = parse_learner(l, &cfg.learner)?;
        }
        if let Some(p) = t.get("protocol").and_then(Value::as_table) {
            cfg.protocol = parse_protocol(p)?;
        }
        if let Some(r) = t.get("runtime").and_then(Value::as_table) {
            cfg.backend = parse_backend(r)?;
            if let Some(n) = get_int(r, "threads") {
                if n < 0 {
                    bail!("runtime.threads must be >= 0 (0 = auto)");
                }
                cfg.threads = n as usize;
            }
        }
        if let Some(v) = get_int(t, "recv_timeout_ms") {
            if v <= 0 {
                bail!("recv_timeout_ms must be >= 1");
            }
            cfg.recv_timeout_ms = v as u64;
        }
        if let Some(v) = get_int(t, "max_retries") {
            if v < 0 {
                bail!("max_retries must be >= 0");
            }
            cfg.max_retries = v as u32;
        }
        if let Some(v) = get_int(t, "serve_clients") {
            if v < 0 {
                bail!("serve_clients must be >= 0");
            }
            cfg.serve_clients = v as usize;
        }
        if let Some(v) = get_int(t, "serve_shards") {
            if v < 0 {
                bail!("serve_shards must be >= 0 (0 = one shard)");
            }
            cfg.serve_shards = v as usize;
        }
        if let Some(f) = t.get("faults").and_then(Value::as_table) {
            cfg.faults = Some(parse_faults(f)?);
        }
        if let Some(entries) = t.get("churn").and_then(Value::as_table_array) {
            cfg.churn = parse_churn(entries)?;
        }
        if let Some(tr) = t.get("transport").and_then(Value::as_table) {
            cfg.transport = parse_transport(tr)?;
        }
        if let Some(g) = t.get("gossip").and_then(Value::as_table) {
            cfg.gossip = Some(parse_gossip(g, cfg.seed)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn get_str<'a>(t: &'a Table, k: &str) -> Option<&'a str> {
    t.get(k).and_then(Value::as_str)
}
fn get_int(t: &Table, k: &str) -> Option<i64> {
    t.get(k).and_then(Value::as_int)
}
fn get_float(t: &Table, k: &str) -> Option<f64> {
    t.get(k).and_then(Value::as_float)
}

fn parse_data(t: &Table) -> Result<DataConfig> {
    match get_str(t, "kind") {
        Some("susy") => Ok(DataConfig::Susy {
            noise: get_float(t, "noise").unwrap_or(0.08),
        }),
        Some("stock") => Ok(DataConfig::Stock {
            stocks: get_int(t, "stocks").unwrap_or(32) as usize,
            noise: get_float(t, "noise").unwrap_or(0.02),
        }),
        Some("hyperplane") => Ok(DataConfig::Hyperplane {
            dim: get_int(t, "dim").unwrap_or(10) as usize,
            drift: get_float(t, "drift").unwrap_or(0.0),
        }),
        Some("mixture") => Ok(DataConfig::Mixture {
            dim: get_int(t, "dim").unwrap_or(2) as usize,
            separation: get_float(t, "separation").unwrap_or(2.0),
        }),
        other => bail!("unknown data kind {other:?}"),
    }
}

fn parse_learner(t: &Table, base: &LearnerConfig) -> Result<LearnerConfig> {
    let mut l = base.clone();
    if let Some(v) = get_float(t, "eta") {
        l.eta = v;
    }
    if let Some(v) = get_float(t, "lambda") {
        l.lambda = v;
    }
    if let Some(v) = t.get("passive_aggressive").and_then(Value::as_bool) {
        l.passive_aggressive = v;
    }
    if let Some(kind) = get_str(t, "kernel") {
        l.kernel = match kind {
            "linear" => KernelConfig::Linear,
            "rbf" => KernelConfig::Rbf {
                gamma: get_float(t, "gamma").unwrap_or(1.0),
            },
            "rff" => KernelConfig::Rff {
                gamma: get_float(t, "gamma").unwrap_or(1.0),
                dim: get_int(t, "rff_dim").unwrap_or(256) as usize,
            },
            other => bail!("unknown kernel `{other}`"),
        };
    }
    if let Some(loss) = get_str(t, "loss") {
        l.loss = match loss {
            "hinge" => LossKind::Hinge,
            "logistic" => LossKind::Logistic,
            "squared" => LossKind::Squared,
            "eps_insensitive" => LossKind::EpsInsensitive(get_float(t, "eps").unwrap_or(0.1)),
            other => bail!("unknown loss `{other}`"),
        };
    }
    if let Some(comp) = get_str(t, "compression") {
        let tau = get_int(t, "tau").unwrap_or(50) as usize;
        l.compression = match comp {
            "none" => CompressionConfig::None,
            "truncation" => CompressionConfig::Truncation { tau },
            "projection" => CompressionConfig::Projection { tau },
            other => bail!("unknown compression `{other}`"),
        };
    }
    Ok(l)
}

fn parse_protocol(t: &Table) -> Result<ProtocolConfig> {
    match get_str(t, "kind") {
        Some("nosync") => Ok(ProtocolConfig::NoSync),
        Some("continuous") => Ok(ProtocolConfig::Continuous),
        Some("periodic") => Ok(ProtocolConfig::Periodic {
            period: get_int(t, "period").unwrap_or(10) as usize,
        }),
        Some("dynamic") => Ok(ProtocolConfig::Dynamic {
            delta: get_float(t, "delta").unwrap_or(0.1),
            check_period: get_int(t, "check_period").unwrap_or(1) as usize,
        }),
        Some("dynamic-decay") => Ok(ProtocolConfig::DynamicDecay {
            delta0: get_float(t, "delta0").unwrap_or(1.0),
            check_period: get_int(t, "check_period").unwrap_or(1) as usize,
        }),
        Some("serial") => Ok(ProtocolConfig::Serial),
        other => bail!("unknown protocol kind {other:?}"),
    }
}

fn parse_fault_link(t: &Table, prefix: &str) -> Result<LinkFaultConfig> {
    let f = |name: &str| get_float(t, &format!("{prefix}_{name}")).unwrap_or(0.0);
    let polls = match get_int(t, &format!("{prefix}_delay_polls")) {
        Some(n) if n >= 1 => n as u32,
        Some(n) => bail!("faults.{prefix}_delay_polls must be >= 1, got {n}"),
        None => 1,
    };
    Ok(LinkFaultConfig {
        drop: f("drop"),
        delay: f("delay"),
        delay_polls: polls,
        duplicate: f("duplicate"),
        reorder: f("reorder"),
        corrupt: f("corrupt"),
    })
}

/// `[faults]` table: flat keys — `seed`, `{up,down}_{drop,delay,
/// delay_polls,duplicate,reorder,corrupt}`, and a `workers = [..]` list
/// restricting injection to those links.
fn parse_faults(t: &Table) -> Result<FaultPlanConfig> {
    let mut f = FaultPlanConfig::clean(get_int(t, "seed").unwrap_or(0) as u64);
    f.up = parse_fault_link(t, "up")?;
    f.down = parse_fault_link(t, "down")?;
    if let Some(v) = t.get("workers") {
        let Value::Array(items) = v else {
            bail!("faults.workers must be an array of worker ids");
        };
        let mut ws = Vec::with_capacity(items.len());
        for it in items {
            match it.as_int() {
                Some(w) if w >= 0 => ws.push(w as usize),
                _ => bail!("faults.workers must be an array of worker ids"),
            }
        }
        f.workers = Some(ws);
    }
    Ok(f)
}

/// `[[churn]]` entries: `worker`, `join`, `leave` (1-based inclusive
/// round window).
fn parse_churn(entries: &[Table]) -> Result<Vec<ChurnEntry>> {
    let mut plan = Vec::with_capacity(entries.len());
    for e in entries {
        let worker = match get_int(e, "worker") {
            Some(w) if w >= 0 => w as usize,
            _ => bail!("churn entry needs a worker id >= 0"),
        };
        let round = |key: &str| match get_int(e, key) {
            Some(r) if r >= 1 => Ok(r as u64),
            _ => bail!("churn entry for worker {worker} needs {key} >= 1"),
        };
        plan.push(ChurnEntry {
            worker,
            join: round("join")?,
            leave: round("leave")?,
        });
    }
    Ok(plan)
}

/// `[transport]` table: `mode = "in-process" | "listen" | "join"`, plus
/// `addr` (listen/join) and `worker` (join).
fn parse_transport(t: &Table) -> Result<TransportConfig> {
    let addr = || match get_str(t, "addr") {
        Some(a) => Ok(a.to_string()),
        None => bail!("transport needs addr (e.g. \"127.0.0.1:7070\")"),
    };
    match get_str(t, "mode") {
        Some("in-process") | None => Ok(TransportConfig::InProcess),
        Some("listen") => Ok(TransportConfig::Listen { addr: addr()? }),
        Some("join") => {
            let worker = match get_int(t, "worker") {
                Some(w) if w >= 0 => w as usize,
                _ => bail!("transport mode \"join\" needs worker >= 0"),
            };
            Ok(TransportConfig::Join {
                addr: addr()?,
                worker,
            })
        }
        Some(other) => bail!("unknown transport mode `{other}`"),
    }
}

/// `[gossip]` table: `topology = "ring" | "torus" | "regular" |
/// "complete"`, `degree` (regular only), `period`, and `seed` (defaults
/// to the experiment seed so one knob reseeds everything).
fn parse_gossip(t: &Table, default_seed: u64) -> Result<GossipConfig> {
    let topology = match get_str(t, "topology") {
        Some(s) => match GossipTopology::parse(s) {
            Some(g) => g,
            None => bail!("unknown gossip topology `{s}`"),
        },
        None => GossipTopology::Ring,
    };
    let degree = match get_int(t, "degree") {
        Some(d) if d >= 1 => d as usize,
        Some(d) => bail!("gossip.degree must be >= 1, got {d}"),
        None => 2,
    };
    let period = match get_int(t, "period") {
        Some(p) if p >= 1 => p as usize,
        Some(p) => bail!("gossip.period must be >= 1, got {p}"),
        None => 1,
    };
    let seed = get_int(t, "seed").map(|v| v as u64).unwrap_or(default_seed);
    Ok(GossipConfig {
        topology,
        degree,
        period,
        seed,
    })
}

fn parse_backend(t: &Table) -> Result<RuntimeBackend> {
    match get_str(t, "backend") {
        Some("native") | None => Ok(RuntimeBackend::Native),
        Some("xla") => Ok(RuntimeBackend::Xla {
            artifacts_dir: get_str(t, "artifacts_dir").unwrap_or("artifacts").to_string(),
            variant: get_str(t, "variant").unwrap_or("susy").to_string(),
        }),
        Some(other) => bail!("unknown backend `{other}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [
            ExperimentConfig::quickstart(),
            ExperimentConfig::fig1_linear(ProtocolConfig::Continuous),
            ExperimentConfig::fig1_kernel(ProtocolConfig::NoSync),
            ExperimentConfig::fig1_dynamic_kernel(0.2),
            ExperimentConfig::fig1_dynamic_kernel_compressed(0.2, 50),
            ExperimentConfig::fig2_kernel(ProtocolConfig::Dynamic {
                delta: 0.05,
                check_period: 1,
            }),
            ExperimentConfig::fig2_linear(ProtocolConfig::Periodic { period: 8 }),
        ] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn toml_roundtrip_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
preset = "fig1"
name = "custom"
learners = 8
rounds = 50

[learner]
eta = 0.2
kernel = "rbf"
gamma = 0.7
compression = "truncation"
tau = 16

[protocol]
kind = "dynamic"
delta = 0.33
check_period = 4

[runtime]
backend = "native"
threads = 3
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "custom");
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.learners, 8);
        assert_eq!(cfg.rounds, 50);
        assert_eq!(cfg.learner.eta, 0.2);
        assert_eq!(cfg.learner.kernel, KernelConfig::Rbf { gamma: 0.7 });
        assert_eq!(
            cfg.learner.compression,
            CompressionConfig::Truncation { tau: 16 }
        );
        assert_eq!(
            cfg.protocol,
            ProtocolConfig::Dynamic {
                delta: 0.33,
                check_period: 4
            }
        );
    }

    #[test]
    fn faults_and_churn_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            r#"
learners = 4
rounds = 100
lockstep = true
recv_timeout_ms = 500
max_retries = 3

[faults]
seed = 9
up_drop = 0.25
up_delay = 0.1
up_delay_polls = 3
down_corrupt = 0.05
workers = [0, 2]

[[churn]]
worker = 1
join = 10
leave = 50

[[churn]]
worker = 2
join = 30
leave = 100
"#,
        )
        .unwrap();
        assert_eq!(cfg.recv_timeout_ms, 500);
        assert_eq!(cfg.max_retries, 3);
        let f = cfg.faults.as_ref().unwrap();
        assert_eq!(f.seed, 9);
        assert_eq!(f.up.drop, 0.25);
        assert_eq!(f.up.delay, 0.1);
        assert_eq!(f.up.delay_polls, 3);
        assert_eq!(f.down.corrupt, 0.05);
        assert_eq!(f.workers, Some(vec![0, 2]));
        assert_eq!(cfg.churn.len(), 2);
        assert_eq!(
            cfg.churn[0],
            ChurnEntry {
                worker: 1,
                join: 10,
                leave: 50
            }
        );
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ExperimentConfig::quickstart();
        c.learners = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::quickstart();
        c.learner.eta = -1.0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::quickstart();
        c.protocol = ProtocolConfig::Dynamic {
            delta: -0.5,
            check_period: 1,
        };
        assert!(c.validate().is_err());

        // Loss/stream mismatch.
        let mut c = ExperimentConfig::quickstart();
        c.learner.loss = LossKind::Squared;
        assert!(c.validate().is_err());

        // Compression on linear model.
        let mut c = ExperimentConfig::fig1_linear(ProtocolConfig::Continuous);
        c.learner.compression = CompressionConfig::Truncation { tau: 8 };
        assert!(c.validate().is_err());

        // Absurd thread counts rejected (0 = auto stays valid).
        let mut c = ExperimentConfig::quickstart();
        c.threads = crate::util::par::MAX_THREADS + 1;
        assert!(c.validate().is_err());

        // Negative TOML threads rejected at parse time (would wrap to
        // usize::MAX through the `as` cast otherwise).
        assert!(ExperimentConfig::from_toml("[runtime]\nthreads = -1\n").is_err());

        // Zero leader timeout is a busy-loop, not a deadline.
        let mut c = ExperimentConfig::quickstart();
        c.recv_timeout_ms = 0;
        assert!(c.validate().is_err());

        // Fault probabilities outside [0, 1] rejected.
        let mut c = ExperimentConfig::quickstart();
        let mut f = FaultPlanConfig::clean(1);
        f.up.drop = 1.5;
        c.faults = Some(f);
        assert!(c.validate().is_err());

        // Churn without lockstep has no round-synchronous plan to follow.
        let mut c = ExperimentConfig::quickstart();
        c.churn = vec![ChurnEntry {
            worker: 0,
            join: 1,
            leave: 10,
        }];
        assert!(c.validate().is_err());

        // Inverted or out-of-range churn windows rejected.
        let mut c = ExperimentConfig::quickstart();
        c.lockstep = true;
        c.churn = vec![ChurnEntry {
            worker: 0,
            join: 50,
            leave: 10,
        }];
        assert!(c.validate().is_err());
        c.churn = vec![ChurnEntry {
            worker: 0,
            join: 1,
            leave: c.rounds as u64 + 1,
        }];
        assert!(c.validate().is_err());
        c.churn = vec![
            ChurnEntry {
                worker: 0,
                join: 1,
                leave: 10,
            },
            ChurnEntry {
                worker: 0,
                join: 20,
                leave: 30,
            },
        ];
        assert!(c.validate().is_err());
        c.churn = vec![ChurnEntry {
            worker: 0,
            join: 2,
            leave: 10,
        }];
        assert!(c.validate().is_ok());

        // Fault injection is in-process only: a socket backend cannot
        // replay a seeded schedule deterministically.
        let mut c = ExperimentConfig::quickstart();
        c.faults = Some(FaultPlanConfig::clean(1));
        c.transport = TransportConfig::Listen {
            addr: "127.0.0.1:7070".into(),
        };
        assert!(c.validate().is_err());

        // Joining worker id must name a real learner slot.
        let mut c = ExperimentConfig::quickstart();
        c.transport = TransportConfig::Join {
            addr: "127.0.0.1:7070".into(),
            worker: c.learners,
        };
        assert!(c.validate().is_err());

        // Empty address is a config mistake, not a bind error.
        let mut c = ExperimentConfig::quickstart();
        c.transport = TransportConfig::Listen { addr: String::new() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn transport_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            r#"
learners = 2
rounds = 20

[transport]
mode = "listen"
addr = "127.0.0.1:7070"
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.transport,
            TransportConfig::Listen {
                addr: "127.0.0.1:7070".into()
            }
        );

        let cfg = ExperimentConfig::from_toml(
            r#"
learners = 2
rounds = 20

[transport]
mode = "join"
addr = "127.0.0.1:7070"
worker = 1
"#,
        )
        .unwrap();
        assert_eq!(
            cfg.transport,
            TransportConfig::Join {
                addr: "127.0.0.1:7070".into(),
                worker: 1
            }
        );

        // join without a worker id, and unknown modes, are parse errors.
        assert!(
            ExperimentConfig::from_toml("[transport]\nmode = \"join\"\naddr = \"x:1\"\n").is_err()
        );
        assert!(ExperimentConfig::from_toml("[transport]\nmode = \"carrier-pigeon\"\n").is_err());
    }

    #[test]
    fn cluster_digest_ignores_transport_side() {
        let mut leader = ExperimentConfig::quickstart();
        leader.transport = TransportConfig::Listen {
            addr: "127.0.0.1:7070".into(),
        };
        let mut worker = ExperimentConfig::quickstart();
        worker.transport = TransportConfig::Join {
            addr: "127.0.0.1:7070".into(),
            worker: 1,
        };
        assert_eq!(leader.cluster_digest(), worker.cluster_digest());

        // ...but any protocol-relevant divergence changes the digest.
        let mut drifted = ExperimentConfig::quickstart();
        drifted.seed += 1;
        assert_ne!(leader.cluster_digest(), drifted.cluster_digest());
    }

    #[test]
    fn gossip_from_toml() {
        let cfg = ExperimentConfig::from_toml(
            r#"
learners = 8
rounds = 40
seed = 99

[data]
kind = "hyperplane"
dim = 6
drift = 0.01

[learner]
kernel = "linear"
loss = "hinge"
compression = "none"

[gossip]
topology = "torus"
period = 5
"#,
        )
        .unwrap();
        let g = cfg.gossip.as_ref().unwrap();
        assert_eq!(g.topology, GossipTopology::Torus);
        assert_eq!(g.period, 5);
        // Topology seed defaults to the experiment seed.
        assert_eq!(g.seed, 99);

        assert!(
            ExperimentConfig::from_toml("[gossip]\ntopology = \"star\"\n").is_err(),
            "unknown topology must be a parse error"
        );
    }

    #[test]
    fn gossip_configs_validated() {
        let base = || {
            let mut c = ExperimentConfig::fig1_linear(ProtocolConfig::NoSync);
            c.learners = 8;
            c.gossip = Some(GossipConfig {
                topology: GossipTopology::Ring,
                degree: 2,
                period: 1,
                seed: 7,
            });
            c
        };
        assert!(base().validate().is_ok());

        // RBF models are variable-size; diffusion needs fixed-size ones.
        let mut c = base();
        c.learner.kernel = KernelConfig::Rbf { gamma: 0.5 };
        c.learner.eta = 0.35;
        assert!(c.validate().is_err());

        // Odd n*k violates the handshake lemma.
        let mut c = base();
        c.learners = 5;
        c.gossip.as_mut().unwrap().topology = GossipTopology::Regular;
        c.gossip.as_mut().unwrap().degree = 3;
        assert!(c.validate().is_err());

        // A prime node count has no torus grid.
        let mut c = base();
        c.learners = 7;
        c.gossip.as_mut().unwrap().topology = GossipTopology::Torus;
        assert!(c.validate().is_err());

        // Leader-runtime modes do not compose with gossip.
        let mut c = base();
        c.lockstep = true;
        assert!(c.validate().is_err());
        let mut c = base();
        c.partial_sync = true;
        assert!(c.validate().is_err());
        let mut c = base();
        c.transport = TransportConfig::Listen {
            addr: "127.0.0.1:7070".into(),
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn protocol_labels() {
        assert_eq!(ProtocolConfig::NoSync.label(), "nosync");
        assert_eq!(
            ProtocolConfig::Periodic { period: 8 }.label(),
            "periodic(b=8)"
        );
        assert!(ProtocolConfig::Dynamic {
            delta: 0.1,
            check_period: 1
        }
        .label()
        .contains("dynamic"));
    }

    #[test]
    fn data_dims() {
        assert_eq!(DataConfig::Susy { noise: 0.0 }.dim(), 18);
        assert_eq!(
            DataConfig::Stock {
                stocks: 32,
                noise: 0.0
            }
            .dim(),
            32
        );
        assert!(DataConfig::Susy { noise: 0.0 }.is_classification());
        assert!(!DataConfig::Stock {
            stocks: 4,
            noise: 0.0
        }
        .is_classification());
    }
}
