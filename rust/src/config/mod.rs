//! Experiment configuration: a TOML-subset parser (offline replacement for
//! `serde` + `toml`) plus the typed experiment schema every entry point of
//! the system — CLI, benches, tests, examples — is driven by.

mod schema;
pub mod toml;

pub use crate::network::fault::{ChurnEntry, FaultPlanConfig, LinkFaultConfig};
pub use schema::{
    CompressionConfig, DataConfig, ExperimentConfig, GossipConfig, GossipTopology, KernelConfig,
    LearnerConfig, LossKind, ProtocolConfig, RuntimeBackend, TransportConfig,
};
pub use toml::{parse as parse_toml, Table, TomlError, Value};
