//! A small TOML-subset parser — enough for KDOL's config files and the
//! artifact manifest, written from scratch because the offline build has no
//! `toml`/`serde` crates.
//!
//! Supported: `[table]` headers, `[[array-of-tables]]` headers, dotted-free
//! bare keys, `=` bindings with string / integer / float / boolean /
//! homogeneous-array values, `#` comments, blank lines. Unsupported TOML
//! (dotted keys, inline tables, multi-line strings, dates) is a parse
//! error, not silent misbehaviour.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(Table),
    /// `[[name]]` array-of-tables.
    TableArray(Vec<Table>),
}

/// A TOML table: ordered map from key to value.
pub type Table = BTreeMap<String, Value>;

/// Parse failure with 1-based line number.
#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError {
        line,
        msg: msg.into(),
    }
}

/// Parse a TOML-subset document into its root table.
pub fn parse(input: &str) -> Result<Table, TomlError> {
    let mut root = Table::new();
    // Path of the table currently being filled ([] = root).
    let mut current: Vec<String> = Vec::new();
    let mut current_is_array = false;

    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim();
            validate_key(name, lineno)?;
            let entry = root
                .entry(name.to_string())
                .or_insert_with(|| Value::TableArray(Vec::new()));
            match entry {
                Value::TableArray(ts) => ts.push(Table::new()),
                _ => return Err(err(lineno, format!("`{name}` is not an array of tables"))),
            }
            current = vec![name.to_string()];
            current_is_array = true;
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim();
            validate_key(name, lineno)?;
            match root
                .entry(name.to_string())
                .or_insert_with(|| Value::Table(Table::new()))
            {
                Value::Table(_) => {}
                _ => return Err(err(lineno, format!("`{name}` is not a table"))),
            }
            current = vec![name.to_string()];
            current_is_array = false;
        } else if let Some(eq) = find_unquoted(line, '=') {
            let key = line[..eq].trim();
            validate_key(key, lineno)?;
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let target = resolve_target(&mut root, &current, current_is_array);
            if target.insert(key.to_string(), value).is_some() {
                return Err(err(lineno, format!("duplicate key `{key}`")));
            }
        } else {
            return Err(err(lineno, format!("cannot parse `{line}`")));
        }
    }
    Ok(root)
}

fn resolve_target<'a>(root: &'a mut Table, path: &[String], is_array: bool) -> &'a mut Table {
    if path.is_empty() {
        return root;
    }
    // kdol-lint: allow(no-unwrap-in-runtime) — parser invariant: the header pass created this table
    match root.get_mut(&path[0]).expect("table created on header") {
        Value::Table(t) => t,
        // kdol-lint: allow(no-unwrap-in-runtime) — parser invariant: a table-array header pushed an element
        Value::TableArray(ts) if is_array => ts.last_mut().expect("pushed on header"),
        // kdol-lint: allow(no-unwrap-in-runtime) — parser invariant: header type checked at creation
        _ => unreachable!("header type checked at creation"),
    }
}

fn strip_comment(line: &str) -> &str {
    match find_unquoted(line, '#') {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Index of `needle` outside of any double-quoted string.
fn find_unquoted(line: &str, needle: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == needle && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn validate_key(key: &str, line: usize) -> Result<(), TomlError> {
    if key.is_empty() {
        return Err(err(line, "empty key"));
    }
    if key
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
    {
        Ok(())
    } else {
        Err(err(line, format!("unsupported key syntax `{key}`")))
    }
}

fn parse_value(s: &str, line: usize) -> Result<Value, TomlError> {
    if s.is_empty() {
        return Err(err(line, "missing value"));
    }
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            return Err(err(line, "unterminated string"));
        };
        if body.contains('"') {
            return Err(err(line, "embedded quotes unsupported"));
        }
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            return Err(err(line, "unterminated array"));
        };
        let body = body.trim();
        let mut items = Vec::new();
        if !body.is_empty() {
            for part in split_top_level(body) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    // Number: int if it parses as i64 and has no float syntax.
    let is_floaty = s.contains('.') || s.contains('e') || s.contains('E');
    if !is_floaty {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(line, format!("cannot parse value `{s}`")))
}

/// Split a flat array body on commas outside quotes (nested arrays are not
/// supported — config never needs them).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

// --- typed accessors --------------------------------------------------------

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_table_array(&self) -> Option<&[Table]> {
        match self {
            Value::TableArray(t) => Some(t),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&Table> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
}

/// Typed lookup helpers over a [`Table`].
pub trait TableExt {
    fn str_of(&self, key: &str) -> anyhow::Result<&str>;
    fn int_of(&self, key: &str) -> anyhow::Result<i64>;
    fn float_of(&self, key: &str) -> anyhow::Result<f64>;
}

impl TableExt for Table {
    fn str_of(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string key `{key}`"))
    }
    fn int_of(&self, key: &str) -> anyhow::Result<i64> {
        self.get(key)
            .and_then(Value::as_int)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer key `{key}`"))
    }
    fn float_of(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Value::as_float)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid float key `{key}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
# experiment
name = "fig1"
learners = 4
delta = 0.25
verbose = true

[protocol]
kind = "dynamic"
check_period = 1
"#;
        let t = parse(doc).unwrap();
        assert_eq!(t["name"], Value::Str("fig1".into()));
        assert_eq!(t["learners"], Value::Int(4));
        assert_eq!(t["delta"], Value::Float(0.25));
        assert_eq!(t["verbose"], Value::Bool(true));
        let proto = t["protocol"].as_table().unwrap();
        assert_eq!(proto["kind"], Value::Str("dynamic".into()));
    }

    #[test]
    fn parses_array_of_tables() {
        let doc = r#"
[[artifact]]
name = "predict_susy"
tau = 64

[[artifact]]
name = "gram_susy"
tau = 64
"#;
        let t = parse(doc).unwrap();
        let arts = t["artifact"].as_table_array().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0]["name"], Value::Str("predict_susy".into()));
        assert_eq!(arts[1]["name"], Value::Str("gram_susy".into()));
    }

    #[test]
    fn parses_arrays() {
        let t = parse("xs = [1, 2, 3]\nys = [0.5, 1.5]\nzs = []\n").unwrap();
        assert_eq!(
            t["xs"],
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(t["zs"], Value::Array(vec![]));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let t = parse("a = \"x # y\" # trailing\n").unwrap();
        assert_eq!(t["a"], Value::Str("x # y".into()));
    }

    #[test]
    fn duplicate_key_is_error() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn bad_syntax_is_error_with_line() {
        let e = parse("ok = 1\nnot a binding\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn scientific_floats() {
        let t = parse("lr = 1e-10\n").unwrap();
        assert_eq!(t["lr"], Value::Float(1e-10));
    }

    #[test]
    fn negative_numbers() {
        let t = parse("a = -3\nb = -0.5\n").unwrap();
        assert_eq!(t["a"], Value::Int(-3));
        assert_eq!(t["b"], Value::Float(-0.5));
    }

    #[test]
    fn typed_accessors() {
        use super::TableExt;
        let t = parse("s = \"x\"\ni = 3\nf = 2.5\n").unwrap();
        assert_eq!(t.str_of("s").unwrap(), "x");
        assert_eq!(t.int_of("i").unwrap(), 3);
        assert_eq!(t.float_of("f").unwrap(), 2.5);
        assert_eq!(t.float_of("i").unwrap(), 3.0); // int coerces to float
        assert!(t.str_of("missing").is_err());
    }
}
