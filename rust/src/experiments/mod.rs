//! Experiment harness: runners for single configurations, the paper's
//! figure reproductions (Fig 1, Fig 2, headline factors), and the
//! ablation sweeps DESIGN.md §4 indexes.

pub mod fig1;
pub mod fig2;
pub mod gossip;
pub mod headline;
pub mod runner;
pub mod sweeps;

pub use runner::{run_experiment, run_serial};
