//! Single-configuration runners, including the serial oracle (one central
//! learner processing all mT examples — the consistency yardstick of
//! Def. 1).

use anyhow::Result;

use crate::config::{ExperimentConfig, ProtocolConfig};
use crate::data::build_streams;
use crate::learner::build_learner;
use crate::metrics::{MetricsRecorder, Outcome};
use crate::network::CommStats;
use crate::protocol::ProtocolEngine;
use crate::util::Stopwatch;

/// Run one experiment to its horizon.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Outcome> {
    // Configure the deterministic parallel backend where the config is
    // consumed (not in the CLI layer), so library callers get the
    // `threads` knob too. Purely a throughput knob: results are bitwise
    // identical at any setting.
    crate::util::par::set_threads(cfg.threads);
    if cfg.protocol == ProtocolConfig::Serial {
        return Ok(run_serial(cfg));
    }
    ProtocolEngine::new(cfg.clone())?.run()
}

/// Serial oracle: a single learner sees the m streams interleaved
/// round-robin (mT examples total). Zero communication by definition;
/// its cumulative loss is the `L_A(mT)` reference in the consistency
/// criterion.
pub fn run_serial(cfg: &ExperimentConfig) -> Outcome {
    let dim = cfg.data.dim();
    let mut learner = build_learner(&cfg.learner, dim, 0);
    let mut streams = build_streams(&cfg.data, cfg.learners, cfg.seed);
    let mut metrics = MetricsRecorder::new(cfg.record_every as u64);
    let comm = CommStats::new();
    let mut watch = Stopwatch::started();
    for round in 1..=(cfg.rounds as u64) {
        for s in streams.iter_mut() {
            let (x, y) = s.next_example();
            let ev = learner.update(&x, y);
            metrics.record_update(ev.loss, ev.error, ev.total_drift(), ev.compression_err);
        }
        metrics.end_round(round, &comm, learner.sv_count() as f64);
    }
    watch.stop();
    Outcome {
        name: format!("{}-serial", cfg.name),
        learners: cfg.learners,
        rounds: cfg.rounds as u64,
        cumulative_loss: metrics.cum_loss,
        cumulative_error: metrics.cum_error,
        cum_drift: metrics.cum_drift,
        cum_compression_err: metrics.cum_compression_err,
        mean_svs: learner.sv_count() as f64,
        comm,
        partial_syncs: 0,
        sync_cache: Default::default(),
        series: metrics.series,
        wall_secs: watch.elapsed_secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_oracle_communicates_nothing() {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.rounds = 40;
        let o = run_serial(&cfg);
        assert_eq!(o.comm.total_bytes(), 0);
        assert!(o.cumulative_loss > 0.0);
        assert_eq!(o.rounds, 40);
    }

    #[test]
    fn run_experiment_dispatches_serial() {
        let mut cfg = ExperimentConfig::quickstart();
        cfg.rounds = 20;
        cfg.protocol = ProtocolConfig::Serial;
        let o = run_experiment(&cfg).unwrap();
        assert!(o.name.ends_with("-serial"));
    }

    #[test]
    fn serial_loss_is_below_isolated_learners() {
        // One learner on mT examples should beat m isolated learners on T
        // each (it sees more data per model) — the premise of Def. 1.
        let mut cfg = ExperimentConfig::quickstart();
        cfg.rounds = 150;
        cfg.learners = 4;
        let serial = run_serial(&cfg);
        cfg.protocol = ProtocolConfig::NoSync;
        let isolated = run_experiment(&cfg).unwrap();
        assert!(
            serial.cumulative_error < isolated.cumulative_error * 1.05,
            "serial {} vs isolated {}",
            serial.cumulative_error,
            isolated.cumulative_error
        );
    }
}
