//! The §4 headline factors of the paper, computed from Fig 2-geometry
//! runs:
//!
//! * kernel vs linear cumulative error — paper: reduction by ~18x;
//! * dynamic-kernel vs continuous-kernel communication — paper: ~2433x;
//! * dynamic-kernel vs linear communication — paper: ~10x smaller;
//! * quiescence round of the dynamic protocol — paper: < 2000.

use anyhow::Result;

use crate::config::{ExperimentConfig, ProtocolConfig};
use crate::experiments::runner::run_experiment;
use crate::metrics::Outcome;

/// The four headline numbers (paper value, measured value).
#[derive(Debug, Clone)]
pub struct Headline {
    pub error_reduction: f64,
    pub comm_reduction_vs_continuous: f64,
    pub comm_vs_linear: f64,
    pub quiescence_round: Option<u64>,
    pub outcomes: Vec<Outcome>,
}

/// Default divergence threshold for the headline systems (tuned on the
/// synthetic stock stream the way the paper tunes on 200 held-out
/// instances; see DESIGN.md §5).
pub const DEFAULT_DELTA: f64 = 0.5;

/// Run the three systems the headline compares and derive the factors.
pub fn run(delta: f64, scale: f64) -> Result<Headline> {
    let mut configs = vec![
        ExperimentConfig::fig2_linear(ProtocolConfig::Dynamic {
            delta,
            check_period: 1,
        }),
        ExperimentConfig::fig2_kernel(ProtocolConfig::Continuous),
        ExperimentConfig::fig2_kernel(ProtocolConfig::Dynamic {
            delta,
            check_period: 1,
        }),
    ];
    for c in configs.iter_mut() {
        c.rounds = ((c.rounds as f64 * scale) as usize).max(100);
    }
    let lin = run_experiment(&configs[0])?;
    let ker_cont = run_experiment(&configs[1])?;
    let ker_dyn = run_experiment(&configs[2])?;

    let error_reduction = lin.cumulative_error / ker_dyn.cumulative_error.max(1e-9);
    let comm_reduction_vs_continuous =
        ker_cont.comm.total_bytes() as f64 / ker_dyn.comm.total_bytes().max(1) as f64;
    let comm_vs_linear =
        lin.comm.total_bytes() as f64 / ker_dyn.comm.total_bytes().max(1) as f64;
    Ok(Headline {
        error_reduction,
        comm_reduction_vs_continuous,
        comm_vs_linear,
        quiescence_round: ker_dyn.quiescent_since(),
        outcomes: vec![lin, ker_cont, ker_dyn],
    })
}

impl Headline {
    pub fn render(&self, rounds_hint: u64) -> String {
        format!(
            "headline factors (paper -> measured)\n\
             error reduction kernel vs linear     : 18x    -> {:.1}x\n\
             comm reduction vs continuous kernel  : 2433x  -> {:.0}x\n\
             comm vs linear system (dyn kernel)   : 10x    -> {:.1}x\n\
             quiescence (last sync round / horizon): <2000/4000 -> {}/{}\n",
            self.error_reduction,
            self.comm_reduction_vs_continuous,
            self.comm_vs_linear,
            self.quiescence_round
                .map(|r| r.to_string())
                .unwrap_or_else(|| "never-synced".into()),
            rounds_hint,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_factors_point_the_right_way() {
        let h = run(DEFAULT_DELTA, 0.1).unwrap();
        // Direction (not magnitude) at 10% scale:
        assert!(
            h.error_reduction > 1.5,
            "kernel should beat linear, got {}x",
            h.error_reduction
        );
        assert!(
            h.comm_reduction_vs_continuous > 1.5,
            "dynamic should cut comm vs continuous, got {}x",
            h.comm_reduction_vs_continuous
        );
        assert!(h.render(400).contains("headline"));
    }
}
