//! Fig 2 reproduction: stock nowcasting, m = 32 learners, SGD updates,
//! linear vs Gaussian-kernel models (truncation to tau = 50), dynamic vs
//! periodic protocols.
//!
//! (a) cumulative error vs cumulative communication,
//! (b) cumulative communication over time — the dynamic protocol reaches
//! quiescence (last sync well before the horizon).

use anyhow::Result;

use crate::config::{ExperimentConfig, ProtocolConfig};
use crate::experiments::runner::run_experiment;
use crate::metrics::Outcome;

/// The system list of Fig 2.
pub fn systems(periods: &[usize], deltas: &[f64]) -> Vec<ExperimentConfig> {
    let mut out = Vec::new();
    for &b in periods {
        out.push(ExperimentConfig::fig2_linear(ProtocolConfig::Periodic {
            period: b,
        }));
        out.push(ExperimentConfig::fig2_kernel(ProtocolConfig::Periodic {
            period: b,
        }));
    }
    for &d in deltas {
        out.push(ExperimentConfig::fig2_linear(ProtocolConfig::Dynamic {
            delta: d,
            check_period: 1,
        }));
        out.push(ExperimentConfig::fig2_kernel(ProtocolConfig::Dynamic {
            delta: d,
            check_period: 1,
        }));
    }
    out
}

/// Run the Fig 2 grid at `scale` of the paper horizon (4000 rounds).
pub fn run(periods: &[usize], deltas: &[f64], scale: f64) -> Result<Vec<Outcome>> {
    let mut outcomes = Vec::new();
    for mut cfg in systems(periods, deltas) {
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(50);
        outcomes.push(run_experiment(&cfg)?);
    }
    Ok(outcomes)
}

pub const DEFAULT_PERIODS: [usize; 2] = [1, 16];
pub const DEFAULT_DELTAS: [f64; 2] = [0.1, 0.5];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_protocol_matrix() {
        let sys = systems(&[1, 8], &[0.05]);
        assert_eq!(sys.len(), 6);
        assert!(sys.iter().any(|c| c.name.contains("linear-periodic")));
        assert!(sys.iter().any(|c| c.name.contains("kernel-dynamic")));
    }

    #[test]
    fn kernel_dynamic_beats_linear_and_cuts_comm() {
        // 5% scale smoke of the Fig 2 story.
        let outcomes = run(&[1], &[0.5], 0.05).unwrap();
        let find = |pat: &str| outcomes.iter().find(|o| o.name.contains(pat)).unwrap();
        let lin = find("linear-periodic(b=1)");
        let ker_per = find("kernel-periodic(b=1)");
        let ker_dyn = find("kernel-dynamic");
        // Kernel model fits the nonlinear target better than linear.
        assert!(ker_per.cumulative_error < lin.cumulative_error);
        // Dynamic communicates less than periodic-1 at comparable loss.
        assert!(ker_dyn.comm.total_bytes() < ker_per.comm.total_bytes());
        assert!(ker_dyn.cumulative_error < 2.0 * ker_per.cumulative_error + 10.0);
    }
}
