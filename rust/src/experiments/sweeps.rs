//! Ablation sweeps (DESIGN.md §4: abl-delta, abl-tau, abl-batch,
//! abl-ref, bound-comm): parameter grids around the Fig 1/Fig 2
//! geometries exposing the protocol's trade-off knobs.

use anyhow::Result;

use crate::config::{CompressionConfig, ExperimentConfig, ProtocolConfig};
use crate::experiments::runner::run_experiment;
use crate::metrics::Outcome;

/// abl-delta: divergence-threshold sweep — the loss/communication
/// trade-off curve of the dynamic protocol. Models are budget-bounded
/// (τ=50) so the sweep isolates Δ: with unbounded expansions the
/// per-round reference evaluations grow O(T) and the sweep's cost blows
/// up O(T^3) without changing the Δ trade-off shape.
pub fn sweep_delta(deltas: &[f64], scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for &d in deltas {
        let mut cfg = ExperimentConfig::fig1_dynamic_kernel_compressed(d, 50);
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

/// abl-tau: compression-budget sweep — model size vs accuracy vs bytes.
pub fn sweep_tau(taus: &[usize], delta: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for &tau in taus {
        let mut cfg = ExperimentConfig::fig1_dynamic_kernel_compressed(delta, tau);
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

/// abl-comp: truncation vs projection at the same budget.
pub fn sweep_compression(tau: usize, delta: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for (label, comp) in [
        ("truncation", CompressionConfig::Truncation { tau }),
        ("projection", CompressionConfig::Projection { tau }),
    ] {
        let mut cfg = ExperimentConfig::fig1_dynamic_kernel(delta);
        cfg.name = format!("fig1-kernel-{label}{tau}-dynamic(Δ={delta})");
        cfg.learner.compression = comp;
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

/// abl-batch: mini-batched local-condition checks (§4) — peak
/// communication vs total communication.
pub fn sweep_check_period(periods: &[usize], delta: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for &b in periods {
        let mut cfg = ExperimentConfig::fig1_kernel(ProtocolConfig::Dynamic {
            delta,
            check_period: b,
        });
        // Budget-bound models: isolates the check-period effect (and keeps
        // the sweep's cost linear in T — see sweep_delta note).
        cfg.learner.compression = CompressionConfig::Truncation { tau: 50 };
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

/// abl-rff: bounded-model alternatives at comparable message size —
/// SV truncation at budget tau vs Random Fourier Features with the
/// byte-equivalent feature count (one SV costs ~(4d + 24) wire bytes vs
/// 4 bytes per RFF weight).
pub fn sweep_rff(tau: usize, delta: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    let mut trunc = ExperimentConfig::fig1_dynamic_kernel_compressed(delta, tau);
    trunc.rounds = ((trunc.rounds as f64 * scale) as usize).max(30);
    let dim = trunc.data.dim();
    out.push(run_experiment(&trunc)?);

    let gamma = match trunc.learner.kernel {
        crate::config::KernelConfig::Rbf { gamma } => gamma,
        // kdol-lint: allow(no-unwrap-in-runtime) — fig1_dynamic_kernel_compressed always builds an RBF config
        _ => unreachable!(),
    };
    // Byte-equivalent feature count.
    let rff_dim = tau * (4 * dim + 24) / 4;
    let mut rff = ExperimentConfig::fig1_kernel(ProtocolConfig::Dynamic {
        delta,
        check_period: 1,
    });
    rff.name = format!("fig1-rff{rff_dim}-dynamic(Δ={delta})");
    rff.learner.kernel = crate::config::KernelConfig::Rff {
        gamma,
        dim: rff_dim,
    };
    rff.learner.compression = CompressionConfig::None;
    rff.rounds = trunc.rounds;
    out.push(run_experiment(&rff)?);
    Ok(out)
}

/// abl-partial: full-sync-only dynamic protocol vs the partial-sync
/// (subset balancing) refinement of [10] at the same threshold.
pub fn sweep_partial(delta: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for partial in [false, true] {
        let mut cfg = ExperimentConfig::fig1_dynamic_kernel_compressed(delta, 50);
        cfg.partial_sync = partial;
        if partial {
            cfg.name = format!("{}-partial", cfg.name);
        }
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

/// abl-decay: fixed threshold vs the consistency schedule
/// Delta_t = Delta_0 / sqrt(t) (Sec. 3 / §4 future work).
pub fn sweep_decay(delta0: f64, scale: f64) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for proto in [
        ProtocolConfig::Dynamic {
            delta: delta0,
            check_period: 1,
        },
        ProtocolConfig::DynamicDecay {
            delta0,
            check_period: 1,
        },
    ] {
        let mut cfg = ExperimentConfig::fig1_kernel(proto);
        cfg.learner.compression = CompressionConfig::Truncation { tau: 50 };
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(30);
        out.push(run_experiment(&cfg)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_sweep_trades_comm_for_loss() {
        let outs = sweep_delta(&[0.02, 2.0], 0.1).unwrap();
        // Larger Delta => less communication.
        assert!(
            outs[1].comm.total_bytes() <= outs[0].comm.total_bytes(),
            "comm: delta=2.0 {} vs delta=0.02 {}",
            outs[1].comm.total_bytes(),
            outs[0].comm.total_bytes()
        );
    }

    #[test]
    fn tau_sweep_bounds_model_size() {
        let outs = sweep_tau(&[8, 32], 0.2, 0.05).unwrap();
        assert!(outs[0].mean_svs <= 8.0 + 1e-9);
        assert!(outs[1].mean_svs <= 32.0 + 1e-9);
    }

    #[test]
    fn check_period_caps_peak_comm() {
        let outs = sweep_check_period(&[1, 8], 0.05, 0.1).unwrap();
        // With b = 8 the protocol can sync at most every 8th round: peak
        // bytes per round can only shrink or stay equal.
        assert!(outs[1].comm.syncs <= outs[0].comm.syncs);
    }

    #[test]
    fn partial_sync_never_increases_full_syncs() {
        let outs = sweep_partial(0.3, 0.1).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs[1].comm.syncs <= outs[0].comm.syncs);
    }

    #[test]
    fn rff_is_fixed_size_and_learns() {
        let outs = sweep_rff(16, 0.5, 0.1).unwrap();
        assert_eq!(outs.len(), 2);
        let rff = &outs[1];
        assert!(rff.name.contains("rff"));
        // RFF models have no support vectors.
        assert_eq!(rff.mean_svs, 0.0);
        // And still learn the nonlinear task (not chance level).
        let rate = rff.cumulative_error / (rff.rounds as f64 * rff.learners as f64);
        assert!(rate < 0.47, "rff error rate {rate}");
    }

    #[test]
    fn decay_schedule_syncs_at_least_as_often_late() {
        let outs = sweep_decay(1.0, 0.1).unwrap();
        assert_eq!(outs.len(), 2);
        // The decaying threshold tightens over time — it can only trigger
        // at least as many syncs as the fixed one with the same Delta_0.
        assert!(outs[1].comm.syncs >= outs[0].comm.syncs);
    }

    #[test]
    fn compression_sweep_runs_both_schemes() {
        let outs = sweep_compression(12, 0.2, 0.05).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs[0].name.contains("truncation"));
        assert!(outs[1].name.contains("projection"));
    }
}
