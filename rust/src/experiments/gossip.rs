//! Gossip-vs-leader comparison: communication against regret for the
//! leaderless diffusion runtime across its seeded topologies, next to a
//! leader full-sync baseline with the same exchange cadence, on two
//! workloads:
//!
//! * **drifting hyperplane** — linear models on the rotating-hyperplane
//!   stream (linear-friendly, but drifting: staying synchronized is what
//!   keeps regret low);
//! * **mixture** — RFF models on the Gaussian-mixture stream (the
//!   kernel-quality hypothesis at fixed message size).
//!
//! Every system runs the same seed, horizon and cadence; the only axis
//! is the communication pattern — star (leader) vs ring / torus /
//! random-regular / complete diffusion — so the table and CSV plot
//! directly as the paper-style communication-vs-regret trade-off.

use anyhow::Result;

use crate::config::{
    DataConfig, ExperimentConfig, GossipConfig, GossipTopology, KernelConfig, LossKind,
    ProtocolConfig,
};
use crate::coordinator::gossip::run_gossip;
use crate::experiments::runner::run_experiment;
use crate::metrics::report::{comparison_table, series_csv};
use crate::metrics::Outcome;

/// The four seeded topology families, in the order the tables report.
pub const TOPOLOGIES: [GossipTopology; 4] = [
    GossipTopology::Ring,
    GossipTopology::Torus,
    GossipTopology::Regular,
    GossipTopology::Complete,
];

/// The two workloads: `(family label, data, kernel)`.
fn families() -> Vec<(&'static str, DataConfig, KernelConfig)> {
    vec![
        (
            "hyperplane-linear",
            DataConfig::Hyperplane {
                dim: 16,
                drift: 0.002,
            },
            KernelConfig::Linear,
        ),
        (
            "mixture-rff",
            DataConfig::Mixture {
                dim: 8,
                separation: 1.5,
            },
            KernelConfig::Rff {
                gamma: 0.5,
                dim: 64,
            },
        ),
    ]
}

/// Shared base config of one family (no gossip section yet).
fn base(family: &str, data: DataConfig, kernel: KernelConfig, m: usize, rounds: usize)
    -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fig1_linear(ProtocolConfig::NoSync);
    cfg.name = format!("gossip-cmp-{family}");
    cfg.seed = 0xD1FF;
    cfg.learners = m;
    cfg.rounds = rounds;
    cfg.record_every = (rounds / 20).max(1);
    cfg.data = data;
    cfg.learner.kernel = kernel;
    cfg.learner.loss = LossKind::Hinge;
    cfg.learner.eta = 0.1;
    cfg
}

/// A degree valid for the random-regular family at any `m >= 4`
/// (handshake lemma: m·k must be even).
pub fn regular_degree(m: usize) -> usize {
    if m % 2 == 0 {
        3.min(m - 1)
    } else {
        2.min(m - 1)
    }
}

/// Run one family: a leader periodic-`period` full-sync baseline plus a
/// gossip run per topology at the same cadence, all on the same seed.
pub fn run_family(family: &str, m: usize, rounds: usize, period: usize) -> Result<Vec<Outcome>> {
    let (label, data, kernel) = families()
        .into_iter()
        .find(|(l, _, _)| *l == family)
        .ok_or_else(|| anyhow::anyhow!("unknown gossip family `{family}`"))?;
    let mut out = Vec::new();

    let mut leader = base(label, data.clone(), kernel, m, rounds);
    leader.name = format!("gossip-cmp-{label}/leader");
    leader.protocol = ProtocolConfig::Periodic { period };
    out.push(run_experiment(&leader)?);

    for topology in TOPOLOGIES {
        let mut cfg = base(label, data.clone(), kernel, m, rounds);
        cfg.gossip = Some(GossipConfig {
            topology,
            degree: regular_degree(m),
            period,
            seed: cfg.seed,
        });
        out.push(run_gossip(&cfg)?.to_outcome());
    }
    Ok(out)
}

/// Run both workloads at `m` nodes.
pub fn run(m: usize, rounds: usize, period: usize) -> Result<Vec<Outcome>> {
    let mut out = Vec::new();
    for (label, _, _) in families() {
        out.extend(run_family(label, m, rounds, period)?);
    }
    Ok(out)
}

/// Render the comparison table plus the over-time CSV (the plottable
/// communication-vs-regret material).
pub fn report(outcomes: &[Outcome]) -> String {
    let refs: Vec<&Outcome> = outcomes.iter().collect();
    let mut s = comparison_table("gossip vs leader: communication vs regret", &refs);
    s.push('\n');
    s.push_str(&series_csv(&refs));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hyperplane_family_compares_leader_and_all_topologies() {
        let outcomes = run_family("hyperplane-linear", 8, 60, 5).unwrap();
        assert_eq!(outcomes.len(), 1 + TOPOLOGIES.len());
        assert!(outcomes[0].name.ends_with("/leader"));
        for o in &outcomes {
            assert!(o.comm.total_bytes() > 0, "{} moved no bytes", o.name);
            assert!(o.cumulative_loss.is_finite());
        }
        // Sparser graphs move fewer bytes per exchange than the clique.
        let find = |pat: &str| {
            outcomes
                .iter()
                .find(|o| o.name.contains(pat))
                .unwrap_or_else(|| panic!("no outcome named *{pat}*"))
        };
        let ring = find("gossip-ring");
        let complete = find("gossip-complete");
        assert!(ring.comm.total_bytes() < complete.comm.total_bytes());

        let rendered = report(&outcomes);
        assert!(rendered.contains("gossip-ring"));
        assert!(rendered.contains("cum_bytes"));
    }

    #[test]
    fn mixture_family_runs_rff_end_to_end() {
        let outcomes = run_family("mixture-rff", 4, 40, 5).unwrap();
        assert_eq!(outcomes.len(), 1 + TOPOLOGIES.len());
        for o in &outcomes {
            assert!(o.cumulative_error.is_finite());
        }
    }

    #[test]
    fn regular_degree_respects_handshake_lemma() {
        for m in 4..20 {
            let k = regular_degree(m);
            assert!(k >= 1 && k < m);
            assert_eq!(m * k % 2, 0, "m={m} k={k}");
        }
    }
}
