//! Fig 1 reproduction: SUSY-like classification, m = 4 learners, 1000
//! instances each.
//!
//! (a) cumulative error vs cumulative communication across systems,
//! (b) cumulative communication over time.
//!
//! Systems, as in the paper's figure: linear models (nosync / continuous /
//! dynamic), kernel models (continuous / dynamic over a Δ-sweep), and
//! kernel + truncation compression (dynamic).

use anyhow::Result;

use crate::config::{ExperimentConfig, ProtocolConfig};
use crate::experiments::runner::run_experiment;
use crate::metrics::Outcome;

/// The system list of Fig 1.
pub fn systems(deltas: &[f64], tau: usize) -> Vec<ExperimentConfig> {
    let mut out = vec![
        ExperimentConfig::fig1_linear(ProtocolConfig::NoSync),
        ExperimentConfig::fig1_linear(ProtocolConfig::Continuous),
        ExperimentConfig::fig1_kernel(ProtocolConfig::NoSync),
        ExperimentConfig::fig1_kernel(ProtocolConfig::Continuous),
    ];
    for &d in deltas {
        out.push(ExperimentConfig::fig1_linear(ProtocolConfig::Dynamic {
            delta: d,
            check_period: 1,
        }));
        out.push(ExperimentConfig::fig1_dynamic_kernel(d));
        out.push(ExperimentConfig::fig1_dynamic_kernel_compressed(d, tau));
    }
    out
}

/// Run the full Fig 1 grid. `scale` shrinks rounds for fast test runs
/// (1.0 = paper geometry: 1000 rounds).
pub fn run(deltas: &[f64], tau: usize, scale: f64) -> Result<Vec<Outcome>> {
    let mut outcomes = Vec::new();
    for mut cfg in systems(deltas, tau) {
        cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(20);
        outcomes.push(run_experiment(&cfg)?);
    }
    Ok(outcomes)
}

/// Default Δ-sweep used by the bench target.
pub const DEFAULT_DELTAS: [f64; 3] = [0.05, 0.2, 0.8];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_contains_all_system_families() {
        let sys = systems(&[0.1], 50);
        let names: Vec<&str> = sys.iter().map(|c| c.name.as_str()).collect();
        assert!(names.iter().any(|n| n.contains("linear-nosync")));
        assert!(names.iter().any(|n| n.contains("linear-continuous")));
        assert!(names.iter().any(|n| n.contains("kernel-continuous")));
        assert!(names.iter().any(|n| n.contains("kernel-dynamic")));
        assert!(names.iter().any(|n| n.contains("trunc50")));
    }

    #[test]
    fn small_scale_run_produces_figure_shape() {
        // The *communication-structure* claims of Fig 1 at 10% scale (the
        // error separation needs the post-transient regime and is asserted
        // in rust/tests/e2e_experiments.rs at larger scale):
        let outcomes = run(&[0.2], 32, 0.1).unwrap();
        let find = |pat: &str| {
            outcomes
                .iter()
                .find(|o| o.name.contains(pat))
                .unwrap_or_else(|| panic!("missing {pat}"))
        };
        let lin_cont = find("linear-continuous");
        let ker_cont = find("kernel-continuous");
        let ker_dyn = find("fig1-kernel-dynamic");
        // Continuous kernel sync is the most expensive system.
        assert!(ker_cont.comm.total_bytes() > lin_cont.comm.total_bytes());
        // Dynamic cuts communication vs continuous kernel.
        assert!(ker_dyn.comm.total_bytes() < ker_cont.comm.total_bytes());
    }
}
