//! Micro-benchmarks of the hot paths: prediction, divergence, averaging,
//! condition tracking (incremental vs naive), wire encoding, and — when
//! artifacts are present — the XLA predict path vs native.
//!
//! Naive pairwise-`Kernel::eval` twins of the dot-product sweeps are
//! benched alongside, so one run shows the blocked-geometry speedup
//! without needing a pre-change checkout. Likewise the cache-cold twin of
//! the cross-event sync cache (`divergence cache-cold` vs `cache-warm`)
//! and the serial twins of the scoped-thread backend (`threads=1` vs
//! `threads=N` — bitwise-identical results, only throughput differs).
//!
//! ```sh
//! cargo bench --bench micro
//! # machine-readable trajectory (appends a run to the history file;
//! # cargo runs the bench with cwd = rust/, so give an absolute path to
//! # hit the committed repo-root skeleton):
//! cargo bench --bench micro -- --json "$PWD/BENCH_3.json" --label post-PR3
//! # CI smoke: tiny budget, throwaway JSON
//! cargo bench --bench micro -- --budget-ms 10 --json /tmp/b.json
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use kdol::bench_util::{bench_for, black_box, report, BenchCli};
use kdol::kernel::{Kernel, Model, SvModel};
use kdol::network::{DeltaDecoder, DeltaEncoder, Message};
use kdol::protocol::configuration_divergence;
use kdol::runtime::{pad_expansion, XlaRuntime};
use kdol::ser::to_bytes;
use kdol::testing::naive;
use kdol::util::{Pcg64, Rng};

/// Globally unique ids across every generated model — the system invariant
/// (ids are minted per learner via `make_sv_id`); reusing ids across
/// models would make the id-merging average conflate distinct points and
/// corrupt the divergence benches.
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

fn random_model(rng: &mut Pcg64, n: usize, d: usize) -> SvModel {
    let mut m = SvModel::new(Kernel::Rbf { gamma: 0.25 }, d);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        m.push(NEXT_ID.fetch_add(1, Ordering::Relaxed), &x, rng.normal());
    }
    m
}

/// Pre-dot-product divergence: Prop. 2 average + naive pairwise distances
/// (the average's self-Gram re-evaluated per learner, as the old
/// implementation did).
fn naive_divergence(models: &[&SvModel]) -> f64 {
    let avg = SvModel::average(models);
    let mut delta = 0.0;
    for m in models {
        delta += naive::distance_sq(m, &avg);
    }
    delta / models.len() as f64
}

fn speedup_line(cli: &BenchCli, what: &str, fast: &str, baseline: &str) {
    if let (Some(f), Some(n)) = (cli.mean_of(fast), cli.mean_of(baseline)) {
        println!(
            "    -> {what}: {:.2}x vs `{baseline}`",
            n.as_secs_f64() / f.as_secs_f64()
        );
    }
}

fn main() {
    let mut cli = BenchCli::from_env("micro", Duration::from_millis(300));
    let budget = cli.budget;
    let mut rng = Pcg64::seeded(1);
    let d = 18;

    // --- prediction ---------------------------------------------------------
    for tau in [50, 200, 800] {
        let model = random_model(&mut rng, tau, d);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let r = bench_for(&format!("predict native tau={tau}"), budget, || {
            black_box(model.predict(black_box(&x)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        let r = bench_for(&format!("predict naive tau={tau}"), budget, || {
            black_box(naive::predict(&model, black_box(&x)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        speedup_line(
            &cli,
            &format!("predict tau={tau}"),
            &format!("predict native tau={tau}"),
            &format!("predict naive tau={tau}"),
        );
    }

    // --- batched prediction (the service's native path) ----------------------
    {
        let model = random_model(&mut rng, 800, d);
        let queries: Vec<Vec<f64>> = (0..64)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let r = bench_for("predict_batch batch=64 tau=800", budget, || {
            black_box(model.predict_batch(black_box(&queries)));
        });
        println!(
            "{} ({:.2} us/query)",
            report(&r),
            r.mean.as_nanos() as f64 / 1000.0 / 64.0
        );
        cli.record(&r);
    }

    // --- divergence (sync-time cost) ----------------------------------------
    for (m, tau) in [(4, 50), (8, 50), (32, 50)] {
        let models: Vec<Model> = (0..m)
            .map(|_| Model::Kernel(random_model(&mut rng, tau, d)))
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let r = bench_for(&format!("divergence m={m} tau={tau}"), budget, || {
            black_box(configuration_divergence(black_box(&refs)));
        });
        println!("{}", report(&r));
        cli.record(&r);
    }
    {
        // Naive twin at m=8 (m=32 naive is ~seconds per iteration; the
        // m=8 ratio already demonstrates the union-Gram win).
        let kernels: Vec<SvModel> = (0..8).map(|_| random_model(&mut rng, 50, d)).collect();
        let krefs: Vec<&SvModel> = kernels.iter().collect();
        let r = bench_for("divergence naive m=8 tau=50", budget, || {
            black_box(naive_divergence(black_box(&krefs)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        speedup_line(
            &cli,
            "divergence m=8 tau=50",
            "divergence m=8 tau=50",
            "divergence naive m=8 tau=50",
        );
    }

    // --- cross-event sync cache: cold vs warm divergence ----------------------
    {
        // Cold: every event rebuilds the union Gram from nothing (the
        // pre-cache behavior, still what standalone kernel_divergence
        // does). Warm: the persistent SyncGramCache keeps the rows and
        // their Gram block across events, so each event pays only the
        // event-view bookkeeping + quadratic forms — O(new SVs * union)
        // kernel entries instead of O(union^2), and here new SVs = 0.
        let kernels: Vec<SvModel> = (0..8).map(|_| random_model(&mut rng, 50, d)).collect();
        let krefs: Vec<&SvModel> = kernels.iter().collect();
        let r = bench_for("divergence cache-cold m=8 tau=50", budget, || {
            black_box(kdol::protocol::divergence::kernel_divergence(black_box(
                &krefs,
            )));
        });
        println!("{}", report(&r));
        cli.record(&r);
        let mut cache = kdol::kernel::SyncGramCache::new(Kernel::Rbf { gamma: 0.25 }, d);
        let r = bench_for("divergence cache-warm m=8 tau=50", budget, || {
            black_box(kdol::protocol::divergence::kernel_divergence_cached(
                &mut cache,
                black_box(&krefs),
            ));
        });
        println!("{}", report(&r));
        cli.record(&r);
        let stats = cache.stats();
        println!(
            "    -> cache after run: {} hits / {} misses (warm events \
             re-evaluate 0 kernel entries)",
            stats.hits, stats.misses
        );
        speedup_line(
            &cli,
            "warm-cache divergence m=8 tau=50",
            "divergence cache-warm m=8 tau=50",
            "divergence cache-cold m=8 tau=50",
        );
    }

    // --- deterministic parallel backend: threaded vs serial sweeps ------------
    {
        use kdol::kernel::Gram;
        use kdol::util::par;
        let n = 512;
        let pts: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        let k = Kernel::Rbf { gamma: 0.25 };
        par::set_threads(1);
        let r = bench_for("gram symmetric n=512 threads=1", budget, || {
            black_box(Gram::compute_symmetric(&k, black_box(&pts), d));
        });
        println!("{}", report(&r));
        cli.record(&r);
        par::set_threads(0); // auto
        let threaded_label = format!("gram symmetric n=512 threads={}", par::threads());
        let r = bench_for(&threaded_label, budget, || {
            black_box(Gram::compute_symmetric(&k, black_box(&pts), d));
        });
        println!("{}", report(&r));
        cli.record(&r);
        speedup_line(
            &cli,
            "threaded gram n=512",
            &threaded_label,
            "gram symmetric n=512 threads=1",
        );

        let model = random_model(&mut rng, 800, d);
        let queries: Vec<Vec<f64>> = (0..64)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        par::set_threads(1);
        let r = bench_for("predict_batch batch=64 tau=800 threads=1", budget, || {
            black_box(model.predict_batch(black_box(&queries)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        par::set_threads(0);
        let threaded_label = format!("predict_batch batch=64 tau=800 threads={}", par::threads());
        let r = bench_for(&threaded_label, budget, || {
            black_box(model.predict_batch(black_box(&queries)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        speedup_line(
            &cli,
            "threaded predict_batch",
            &threaded_label,
            "predict_batch batch=64 tau=800 threads=1",
        );
    }

    // --- averaging ------------------------------------------------------------
    for m in [4, 32] {
        let models: Vec<Model> = (0..m)
            .map(|_| Model::Kernel(random_model(&mut rng, 50, d)))
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let r = bench_for(&format!("average m={m} tau=50"), budget, || {
            black_box(Model::average(black_box(&refs)));
        });
        println!("{}", report(&r));
        cli.record(&r);
    }

    // --- condition check: incremental vs naive -------------------------------
    {
        let f = random_model(&mut rng, 50, d);
        let refm = random_model(&mut rng, 50, d);
        let r = bench_for("norm_diff naive tau=50 (per-round if naive)", budget, || {
            black_box(f.distance_sq(black_box(&refm)));
        });
        println!("{}", report(&r));
        cli.record(&r);
        // `distance_sq_with_norms` with both norms in hand: the cross
        // inner product alone (what the trackers/leader now pay).
        let (nf, nr) = (f.norm_sq(), refm.norm_sq());
        let r = bench_for("norm_diff cached-norms tau=50", budget, || {
            black_box(f.distance_sq_with_norms(black_box(&refm), nf, nr));
        });
        println!("{}", report(&r));
        cli.record(&r);
        // Incremental path cost ~ one reference evaluation.
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let r = bench_for("tracker incremental (one r(x) eval)", budget, || {
            black_box(refm.predict(black_box(&x)));
        });
        println!("{}", report(&r));
        cli.record(&r);
    }

    // --- wire encoding ----------------------------------------------------------
    {
        let model = random_model(&mut rng, 50, d);
        let mut enc = DeltaEncoder::new();
        let (coeffs, block) = enc.encode_upload(&model);
        let msg = Message::ModelUpload {
            learner: 0,
            round: 1,
            coeffs,
            new_svs: block,
        };
        let r = bench_for("encode ModelUpload tau=50", budget, || {
            black_box(to_bytes(black_box(&msg)));
        });
        println!("{} ({} bytes)", report(&r), msg.wire_bytes());
        cli.record(&r);

        let mut dec = DeltaDecoder::new(1);
        let (coeffs, block) = match &msg {
            Message::ModelUpload {
                coeffs, new_svs, ..
            } => (coeffs.clone(), new_svs.clone()),
            _ => unreachable!(),
        };
        let template = SvModel::new(Kernel::Rbf { gamma: 0.25 }, d);
        let r = bench_for("ingest upload tau=50", budget, || {
            black_box(
                dec.ingest_upload(0, black_box(&coeffs), black_box(&block), &template)
                    .unwrap(),
            );
        });
        println!("{}", report(&r));
        cli.record(&r);
    }

    // --- XLA vs native predict (needs artifacts) --------------------------------
    let dir = XlaRuntime::default_dir();
    if dir.join("manifest.toml").exists() {
        let rt = XlaRuntime::load(&dir, "susy").expect("load artifacts");
        let spec = rt.spec("predict").unwrap().clone();
        let model = random_model(&mut rng, spec.tau, spec.d);
        let (svs, alphas) = pad_expansion(&model, spec.tau).unwrap();
        let x: Vec<f32> = (0..spec.batch * spec.d)
            .map(|_| rng.normal() as f32)
            .collect();
        let r = bench_for(
            &format!("predict XLA batch={} tau={}", spec.batch, spec.tau),
            budget,
            || {
                black_box(rt.predict(&svs, &alphas, black_box(&x), 0.25).unwrap());
            },
        );
        println!(
            "{} ({:.2} us/query)",
            report(&r),
            r.mean.as_micros() as f64 / spec.batch as f64
        );
        cli.record(&r);
        let queries: Vec<Vec<f64>> = (0..spec.batch)
            .map(|_| (0..spec.d).map(|_| rng.normal()).collect())
            .collect();
        let r = bench_for(
            &format!("predict native batch={} tau={}", spec.batch, spec.tau),
            budget,
            || {
                black_box(model.predict_batch(black_box(&queries)));
            },
        );
        println!(
            "{} ({:.2} us/query)",
            report(&r),
            r.mean.as_micros() as f64 / spec.batch as f64
        );
        cli.record(&r);
    } else {
        println!("(skipping XLA benches — run `make artifacts`)");
    }

    cli.finish().expect("writing bench JSON");
}
