//! Micro-benchmarks of the hot paths: prediction, divergence, averaging,
//! condition tracking (incremental vs naive), wire encoding, and — when
//! artifacts are present — the XLA predict path vs native.
//!
//! ```sh
//! cargo bench --bench micro
//! ```

use std::time::Duration;

use kdol::bench_util::{bench_for, black_box, report};
use kdol::kernel::{Kernel, Model, SvModel};
use kdol::network::{DeltaDecoder, DeltaEncoder, Message};
use kdol::protocol::configuration_divergence;
use kdol::runtime::{pad_expansion, XlaRuntime};
use kdol::ser::to_bytes;
use kdol::util::{Pcg64, Rng};

const BUDGET: Duration = Duration::from_millis(300);

fn random_model(rng: &mut Pcg64, n: usize, d: usize) -> SvModel {
    let mut m = SvModel::new(Kernel::Rbf { gamma: 0.25 }, d);
    for i in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        m.push(i as u64 + 1, &x, rng.normal());
    }
    m
}

fn main() {
    let mut rng = Pcg64::seeded(1);
    let d = 18;

    // --- prediction ---------------------------------------------------------
    for tau in [50, 200, 800] {
        let model = random_model(&mut rng, tau, d);
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let r = bench_for(&format!("predict native tau={tau}"), BUDGET, || {
            black_box(model.predict(black_box(&x)));
        });
        println!("{}", report(&r));
    }

    // --- divergence (sync-time cost) ----------------------------------------
    for (m, tau) in [(4, 50), (8, 50), (32, 50)] {
        let models: Vec<Model> = (0..m)
            .map(|_| Model::Kernel(random_model(&mut rng, tau, d)))
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let r = bench_for(&format!("divergence m={m} tau={tau}"), BUDGET, || {
            black_box(configuration_divergence(black_box(&refs)));
        });
        println!("{}", report(&r));
    }

    // --- averaging ------------------------------------------------------------
    for m in [4, 32] {
        let models: Vec<Model> = (0..m)
            .map(|_| Model::Kernel(random_model(&mut rng, 50, d)))
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let r = bench_for(&format!("average m={m} tau=50"), BUDGET, || {
            black_box(Model::average(black_box(&refs)));
        });
        println!("{}", report(&r));
    }

    // --- condition check: incremental vs naive -------------------------------
    {
        let f = random_model(&mut rng, 50, d);
        let refm = random_model(&mut rng, 50, d);
        let r = bench_for("norm_diff naive tau=50 (per-round if naive)", BUDGET, || {
            black_box(f.distance_sq(black_box(&refm)));
        });
        println!("{}", report(&r));
        // Incremental path cost ~ one reference evaluation.
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let r = bench_for("tracker incremental (one r(x) eval)", BUDGET, || {
            black_box(refm.predict(black_box(&x)));
        });
        println!("{}", report(&r));
    }

    // --- wire encoding ----------------------------------------------------------
    {
        let model = random_model(&mut rng, 50, d);
        let mut enc = DeltaEncoder::new();
        let (coeffs, block) = enc.encode_upload(&model);
        let msg = Message::ModelUpload {
            learner: 0,
            round: 1,
            coeffs,
            new_svs: block,
        };
        let r = bench_for("encode ModelUpload tau=50", BUDGET, || {
            black_box(to_bytes(black_box(&msg)));
        });
        println!("{} ({} bytes)", report(&r), msg.wire_bytes());

        let mut dec = DeltaDecoder::new(1);
        let (coeffs, block) = match &msg {
            Message::ModelUpload {
                coeffs, new_svs, ..
            } => (coeffs.clone(), new_svs.clone()),
            _ => unreachable!(),
        };
        let template = SvModel::new(Kernel::Rbf { gamma: 0.25 }, d);
        let r = bench_for("ingest upload tau=50", BUDGET, || {
            black_box(
                dec.ingest_upload(0, black_box(&coeffs), black_box(&block), &template)
                    .unwrap(),
            );
        });
        println!("{}", report(&r));
    }

    // --- XLA vs native predict (needs artifacts) --------------------------------
    let dir = XlaRuntime::default_dir();
    if dir.join("manifest.toml").exists() {
        let rt = XlaRuntime::load(&dir, "susy").expect("load artifacts");
        let spec = rt.spec("predict").unwrap().clone();
        let model = random_model(&mut rng, spec.tau, spec.d);
        let (svs, alphas) = pad_expansion(&model, spec.tau).unwrap();
        let x: Vec<f32> = (0..spec.batch * spec.d)
            .map(|_| rng.normal() as f32)
            .collect();
        let r = bench_for(
            &format!("predict XLA batch={} tau={}", spec.batch, spec.tau),
            BUDGET,
            || {
                black_box(rt.predict(&svs, &alphas, black_box(&x), 0.25).unwrap());
            },
        );
        println!(
            "{} ({:.2} us/query)",
            report(&r),
            r.mean.as_micros() as f64 / spec.batch as f64
        );
        let queries: Vec<Vec<f64>> = (0..spec.batch)
            .map(|_| (0..spec.d).map(|_| rng.normal()).collect())
            .collect();
        let r = bench_for(
            &format!("predict native batch={} tau={}", spec.batch, spec.tau),
            BUDGET,
            || {
                for q in &queries {
                    black_box(model.predict(black_box(q)));
                }
            },
        );
        println!(
            "{} ({:.2} us/query)",
            report(&r),
            r.mean.as_micros() as f64 / spec.batch as f64
        );
    } else {
        println!("(skipping XLA benches — run `make artifacts`)");
    }
}
