//! Serving-tier benchmarks: end-to-end load throughput and latency
//! quantiles under snapshot swap churn at 1 vs 4 shards, plus the
//! publish / hot-read / skipped-republish micro costs of the RCU
//! snapshot cell.
//!
//! ```sh
//! cargo bench --bench serve
//! # machine-readable trajectory (cargo runs benches with cwd = rust/,
//! # so give an absolute path to hit the committed repo-root skeleton):
//! cargo bench --bench serve -- --json "$PWD/BENCH_8.json" --label post-PR8
//! # CI smoke: tiny budget
//! cargo bench --bench serve -- --budget-ms 50 --label ci-smoke --json /tmp/b.json
//! ```

use std::sync::Arc;
use std::time::Duration;

use kdol::bench_util::{bench_for, black_box, report, BenchCli, BenchResult};
use kdol::coordinator::serving::load::{run_load, seeded_model, LoadConfig};
use kdol::coordinator::serving::snapshot::{SnapshotCell, SnapshotReader};

fn main() {
    let mut cli = BenchCli::from_env("serve", Duration::from_millis(300));
    let budget = cli.budget;
    // Each load scenario runs for about one bench budget of wall time.
    let duration = budget.max(Duration::from_millis(40));

    // --- end-to-end load: throughput + latency under swap churn -------------
    for shards in [1usize, 4] {
        let cfg = LoadConfig {
            clients: 16,
            shards,
            duration,
            seed: 7,
            swap_every: Some(Duration::from_millis(10)),
            dim: 8,
            svs: 64,
            gamma: 0.25,
        };
        let rep = run_load(&cfg).expect("serve load scenario");
        let lat = rep.serving.latency;
        let per_pred = if rep.predictions == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos((rep.elapsed.as_nanos() / rep.predictions as u128) as u64)
        };
        let thr = BenchResult {
            name: format!("serve throughput shards={shards} clients=16"),
            iters: rep.predictions as usize,
            mean: per_pred,
            p50: per_pred,
            p99: per_pred,
            min: per_pred,
        };
        println!(
            "{} ({:.0} pred/s, {} swaps, {} skipped republishes)",
            report(&thr),
            rep.throughput_per_sec(),
            rep.serving.swaps,
            rep.serving.skipped_repads
        );
        cli.record(&thr);
        let latr = BenchResult {
            name: format!("serve latency shards={shards} clients=16"),
            iters: lat.count as usize,
            mean: Duration::from_nanos(lat.mean_ns),
            p50: Duration::from_nanos(lat.p50_ns),
            p99: Duration::from_nanos(lat.p99_ns),
            // Per-query minima are not tracked by the histogram; p50 is
            // the recorded floor proxy.
            min: Duration::from_nanos(lat.p50_ns),
        };
        println!(
            "{} (queue high-water {})",
            report(&latr),
            rep.serving.queue_high_water
        );
        cli.record(&latr);
    }

    // --- RCU snapshot cell micro costs ---------------------------------------
    {
        let model = seeded_model(1, 64, 18, 0.25);
        let cell = Arc::new(SnapshotCell::new(model.clone(), None));
        let r = bench_for("snapshot publish tau=64 (clone + swap)", budget, || {
            black_box(cell.publish(model.clone(), None));
        });
        println!("{}", report(&r));
        cli.record(&r);

        let mut reader = SnapshotReader::new(Arc::clone(&cell));
        let r = bench_for("snapshot read hot path (version check)", budget, || {
            black_box(reader.snapshot().version);
        });
        println!("{}", report(&r));
        cli.record(&r);

        // Bitwise-identical republish: the skip must cost a comparison,
        // not a snapshot construction.
        let identical = seeded_model(1, 64, 18, 0.25);
        let r = bench_for("snapshot republish identical tau=64", budget, || {
            let skipped = cell
                .publish_if_changed(identical.clone(), |_| Ok(None))
                .expect("publish_if_changed");
            black_box(skipped);
        });
        println!("{}", report(&r));
        cli.record(&r);
    }

    cli.finish().expect("writing bench JSON");
}
