//! Gossip-runtime benchmarks: wall time, communicated bytes, and
//! cumulative loss (the regret proxy) of one in-process diffusion run
//! per topology family at m = 8 and m = 32 nodes. Bytes and loss ride
//! in the result names, so the committed `BENCH_10.json` trajectory
//! doubles as the communication-vs-regret record per PR.
//!
//! ```sh
//! cargo bench --bench gossip
//! # machine-readable trajectory (cargo runs benches with cwd = rust/,
//! # so give an absolute path to hit the committed repo-root skeleton):
//! cargo bench --bench gossip -- --json "$PWD/BENCH_10.json" --label post-PR10
//! # CI smoke: tiny budget
//! cargo bench --bench gossip -- --budget-ms 50 --label ci-smoke --json /tmp/b.json
//! ```

use std::time::Duration;

use kdol::bench_util::{report, BenchCli, BenchResult};
use kdol::config::{GossipConfig, ProtocolConfig};
use kdol::coordinator::run_gossip;
use kdol::experiments::gossip::{regular_degree, TOPOLOGIES};

fn main() {
    let mut cli = BenchCli::from_env("gossip", Duration::from_millis(300));
    // One diffusion run per (topology, m); the budget scales the horizon
    // so `--budget-ms 50` smoke stays quick while a default run measures
    // something real.
    let rounds = (cli.budget.as_millis() as usize).clamp(60, 600);

    for m in [8usize, 32] {
        for topology in TOPOLOGIES {
            let mut cfg = kdol::config::ExperimentConfig::fig1_linear(ProtocolConfig::NoSync);
            cfg.name = "bench-gossip".into();
            cfg.learner.kernel = kdol::config::KernelConfig::Linear;
            cfg.learners = m;
            cfg.rounds = rounds;
            cfg.record_every = rounds;
            cfg.gossip = Some(GossipConfig {
                topology,
                degree: regular_degree(m),
                period: 5,
                seed: cfg.seed,
            });
            let out = run_gossip(&cfg).expect("gossip bench run");
            let wall = Duration::from_secs_f64(out.wall_secs.max(1e-9));
            let per_round = wall / rounds as u32;
            let r = BenchResult {
                name: format!(
                    "gossip {} m={m} bytes={} cumloss={:.1}",
                    topology.label(),
                    out.comm.total_bytes(),
                    out.cum_loss
                ),
                iters: rounds,
                mean: per_round,
                p50: per_round,
                p99: per_round,
                min: per_round,
            };
            println!(
                "{} ({} exchanges over {} directed edges, consensus {:.2e})",
                report(&r),
                out.exchanges,
                out.directed_edges,
                out.consensus_sq
            );
            cli.record(&r);
        }
    }

    cli.finish().expect("writing bench JSON");
}
