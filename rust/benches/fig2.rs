//! Bench target for Fig 2: the stock-nowcasting experiment (m=32) — the
//! error/communication matrix (2a), the over-time series (2b), and the §4
//! headline factors.
//!
//! ```sh
//! cargo bench --bench fig2
//! KDOL_BENCH_SCALE=0.25 cargo bench --bench fig2
//! ```

use kdol::experiments::{fig2, headline};
use kdol::metrics::report::{comparison_table, series_csv, write_report};
use kdol::metrics::Outcome;
use kdol::util::Stopwatch;

fn main() {
    let scale: f64 = std::env::var("KDOL_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let mut watch = Stopwatch::started();
    let outcomes =
        fig2::run(&fig2::DEFAULT_PERIODS, &fig2::DEFAULT_DELTAS, scale).expect("fig2 run");
    let refs: Vec<&Outcome> = outcomes.iter().collect();
    println!(
        "{}",
        comparison_table(
            &format!("Fig 2 (scale {scale}) — stock nowcasting, m=32"),
            &refs
        )
    );
    write_report(
        std::path::Path::new("target/bench_fig2_series.csv"),
        &series_csv(&refs),
    )
    .expect("write series");
    println!("(b) over-time series -> target/bench_fig2_series.csv");

    let h = headline::run(headline::DEFAULT_DELTA, scale).expect("headline");
    println!("{}", h.render((4000.0 * scale) as u64));
    watch.stop();
    println!("total bench wall time: {:.1}s", watch.elapsed_secs());
}
