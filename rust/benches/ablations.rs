//! Ablation bench: the DESIGN.md §4 sweeps (Δ threshold, compression
//! budget τ, mini-batched condition checks, truncation-vs-projection) and
//! the Prop. 6 / Thm. 7 bound verification table.
//!
//! ```sh
//! cargo bench --bench ablations
//! KDOL_BENCH_SCALE=0.2 cargo bench --bench ablations
//! ```

use kdol::config::{ExperimentConfig, ProtocolConfig};
use kdol::experiments::{runner, sweeps};
use kdol::metrics::report::comparison_table;
use kdol::metrics::{EfficiencyReport, Outcome};

fn main() {
    let scale: f64 = std::env::var("KDOL_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);

    let show = |title: &str, outs: &[Outcome]| {
        let refs: Vec<&Outcome> = outs.iter().collect();
        println!("{}", comparison_table(title, &refs));
    };

    show(
        "abl-delta: divergence-threshold sweep (dynamic, kernel)",
        &sweeps::sweep_delta(&[0.01, 0.05, 0.2, 0.8, 3.2], scale).expect("delta sweep"),
    );
    show(
        "abl-tau: compression budget sweep (dynamic Δ=0.2)",
        &sweeps::sweep_tau(&[10, 25, 50, 100, 200], 0.2, scale).expect("tau sweep"),
    );
    show(
        "abl-batch: mini-batched condition checks (Δ=0.05)",
        &sweeps::sweep_check_period(&[1, 4, 16, 64], 0.05, scale).expect("check sweep"),
    );
    show(
        "abl-comp: truncation vs projection (τ=50, Δ=0.2)",
        &sweeps::sweep_compression(50, 0.2, scale).expect("comp sweep"),
    );

    // bound-comm: measured vs analytic bounds + consistency ratio.
    let delta = 0.2;
    let mut cfg = ExperimentConfig::fig1_dynamic_kernel_compressed(delta, 50);
    cfg.rounds = ((cfg.rounds as f64 * scale) as usize).max(50);
    let outcome = runner::run_experiment(&cfg).expect("bounds run");
    let mut serial_cfg = cfg.clone();
    serial_cfg.protocol = ProtocolConfig::Serial;
    let serial = runner::run_serial(&serial_cfg);
    let rep = EfficiencyReport::evaluate(
        &outcome,
        cfg.learner.eta,
        delta,
        (outcome.mean_svs as usize + 1) * cfg.learners,
        cfg.data.dim(),
        Some(serial.cumulative_loss),
    );
    println!("== bound-comm: Prop. 6 / Thm. 7 / Def. 1 ==");
    for c in &rep.checks {
        println!(
            "{:<42} measured {:>16.1}  bound {:>16.1}  slack {:>9.2}x  [{}]",
            c.name,
            c.measured,
            c.bound,
            c.slack(),
            if c.holds() { "holds" } else { "VIOLATED" }
        );
    }
    if let Some(r) = rep.consistency_ratio {
        println!("consistency L_D(T,m) / L_serial(mT) = {r:.3}");
    }
    assert!(rep.all_hold(), "a paper bound was violated — investigate!");
}
