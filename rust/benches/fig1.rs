//! Bench target for Fig 1: regenerates the error-vs-communication table
//! (1a) and emits the over-time series CSV (1b) at the paper's geometry.
//!
//! ```sh
//! cargo bench --bench fig1            # full paper scale (m=4, T=1000)
//! KDOL_BENCH_SCALE=0.1 cargo bench --bench fig1
//! ```

use kdol::experiments::fig1;
use kdol::metrics::report::{comparison_table, series_csv, write_report};
use kdol::metrics::Outcome;
use kdol::util::Stopwatch;

fn main() {
    let scale: f64 = std::env::var("KDOL_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);
    let mut watch = Stopwatch::started();
    let outcomes = fig1::run(&fig1::DEFAULT_DELTAS, 50, scale).expect("fig1 run");
    watch.stop();
    let refs: Vec<&Outcome> = outcomes.iter().collect();
    println!(
        "{}",
        comparison_table(
            &format!("Fig 1 (scale {scale}) — SUSY-like, m=4, T=1000/learner"),
            &refs
        )
    );
    println!("(a) pareto points: (cum-error, comm-bytes) per system above");
    println!("(b) over-time series -> target/bench_fig1_series.csv");
    write_report(
        std::path::Path::new("target/bench_fig1_series.csv"),
        &series_csv(&refs),
    )
    .expect("write series");
    println!("total bench wall time: {:.1}s", watch.elapsed_secs());
}
