//! Gossip ↔ leader parity: on a **complete graph with full attendance**,
//! one diffusion exchange must be bit-for-bit the leader's `sync_linear`
//! quantized wire average — both reduce the same `from_wire`-widened
//! wire models in ascending node order through `LinearModel::average`,
//! quantize once, and adopt the widened result.
//!
//! The pin runs at two levels:
//!
//! * **math** — `protocol::gossip::combine` on a uniform Metropolis row
//!   vs `LinearModel::average` on the same operands;
//! * **runtime** — a full `run_gossip` on the complete graph vs
//!   `run_cluster` under `Periodic { period }` on the same config, with
//!   `period | rounds` so the horizon ends on a synchronization: every
//!   node's final wire model must equal the cluster's `final_model`
//!   wire exactly, for plain linear and for RFF learners.

use kdol::config::{
    CompressionConfig, ExperimentConfig, GossipConfig, GossipTopology, KernelConfig, ProtocolConfig,
};
use kdol::coordinator::{run_cluster, run_gossip};
use kdol::kernel::LinearModel;
use kdol::protocol::gossip::combine;
use kdol::protocol::Topology;

/// Base config of one parity scenario; the caller picks the runtime by
/// setting either `protocol` (leader) or `gossip` (diffusion).
fn base(kernel: KernelConfig, m: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::fig1_linear(ProtocolConfig::NoSync);
    cfg.name = "parity-gossip".into();
    cfg.learners = m;
    cfg.rounds = rounds;
    cfg.record_every = rounds.max(1);
    cfg.learner.kernel = kernel;
    cfg.learner.compression = CompressionConfig::None;
    cfg
}

/// Run both systems on the same seed/data at cadence `period` and
/// assert the final models agree bitwise.
fn assert_final_model_parity(kernel: KernelConfig, m: usize, rounds: usize, period: usize) {
    assert_eq!(rounds % period, 0, "horizon must end on a sync");

    let mut leader = base(kernel, m, rounds);
    leader.protocol = ProtocolConfig::Periodic { period };
    let cluster = run_cluster(&leader).unwrap();
    let reference = cluster
        .final_model
        .as_ref()
        .expect("periodic run ends on a full sync")
        .as_linear()
        .expect("fixed-size parity scenario")
        .to_wire();

    let mut diffused = base(kernel, m, rounds);
    diffused.gossip = Some(GossipConfig {
        topology: GossipTopology::Complete,
        degree: 0,
        period,
        seed: diffused.seed,
    });
    let gossip = run_gossip(&diffused).unwrap();

    assert_eq!(gossip.exchanges, (rounds / period) as u64, "exchange count");
    assert_eq!(gossip.consensus_sq, 0.0, "complete graph must reach consensus");
    for (node, w) in gossip.final_w.iter().enumerate() {
        assert_eq!(
            w, &reference,
            "node {node}: complete-graph diffusion diverged from the leader average"
        );
    }
}

#[test]
fn complete_graph_single_exchange_matches_leader_linear() {
    // One exchange at the horizon: the purest form of the pin.
    assert_final_model_parity(KernelConfig::Linear, 4, 40, 40);
}

#[test]
fn complete_graph_repeated_exchanges_match_leader_linear() {
    // Every exchange adopts the same average as the leader's sync, so
    // the trajectories stay identical by induction across 12 syncs.
    assert_final_model_parity(KernelConfig::Linear, 4, 60, 5);
}

#[test]
fn complete_graph_exchanges_match_leader_rff() {
    // RFF learners are fixed-size in feature space: the same wire path,
    // at the feature dimension instead of the input dimension.
    let kernel = KernelConfig::Rff {
        gamma: 0.25,
        dim: 32,
    };
    assert_final_model_parity(kernel, 3, 60, 10);
}

#[test]
fn uniform_row_combine_is_the_leader_average_bitwise() {
    // Math-level pin on a real topology's Metropolis row: the complete
    // graph's row is uniform, so `combine` must take the exact
    // `LinearModel::average` sum-then-scale path.
    let n = 5;
    let dim = 7;
    let topo = Topology::build(GossipTopology::Complete, n, 0, 3).unwrap();
    let weights = topo.metropolis_weights();
    let wires: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            (0..dim)
                .map(|j| ((i * dim + j) as f32).mul_add(0.125, -2.0))
                .collect()
        })
        .collect();

    let models: Vec<LinearModel> = wires.iter().map(|w| LinearModel::from_wire(w)).collect();
    let refs: Vec<&LinearModel> = models.iter().collect();
    let leader = LinearModel::average(&refs).to_wire();

    for node in 0..n {
        let contribs: Vec<(usize, &[f32])> =
            wires.iter().enumerate().map(|(i, w)| (i, w.as_slice())).collect();
        let combined = combine(node, &weights[node], &contribs).unwrap().to_wire();
        assert_eq!(combined, leader, "node {node}");
    }
}
