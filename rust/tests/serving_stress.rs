//! Serving-tier stress tests: submit/flush hammering against concurrent
//! snapshot publishes.
//!
//! The contracts under fire (see `coordinator/serving/`):
//!
//! * **No torn models.** Every fulfilled score is attributable to exactly
//!   one published snapshot: the `(score, version)` pair a client gets
//!   back must bitwise-match what *that* version's model predicts for the
//!   query. A half-swapped model would produce a score matching no
//!   published version.
//! * **No stall.** Publishing never blocks serving: clients complete a
//!   fixed amount of work while the publisher churns through swaps.
//! * **Shard-count invariance.** Per-query scores are bitwise-equal to
//!   serial `predict_batch` (and to the single-shard facade's native
//!   path) at any shard count.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use kdol::coordinator::serving::load::seeded_model;
use kdol::coordinator::serving::shard::Ticket;
use kdol::coordinator::{PredictionService, ScorePath, ServingConfig, ServingTier};
use kdol::kernel::{Kernel, SvModel};
use kdol::util::{Pcg64, Rng};

/// Single-SV RBF model; distinct `alpha` values give distinct (and thus
/// bitwise-distinguishable) scores for any fixed probe query.
fn probe_model(alpha: f64) -> SvModel {
    let mut m = SvModel::new(Kernel::Rbf { gamma: 0.5 }, 4);
    m.push(1, &[0.5, -0.5, 0.25, 1.0], alpha);
    m
}

#[test]
fn hammer_never_observes_torn_model_and_never_stalls() {
    const CLIENTS: u64 = 4;
    const ITERS: usize = 1000;
    let probe = vec![0.4, -0.2, 0.9, 0.1];
    let models: Vec<SvModel> = (0..10).map(|k| probe_model(0.25 + 0.5 * k as f64)).collect();

    // version -> the only score bits that version may ever produce.
    let mut expect: HashMap<u64, u64> = HashMap::new();
    expect.insert(1, models[0].predict(&probe).to_bits());

    let cfg = ServingConfig {
        shards: 2,
        batch: 4,
        ..ServingConfig::default()
    };
    let tier = Arc::new(ServingTier::start(models[0].clone(), &cfg));

    let mut clients = Vec::new();
    for client in 0..CLIENTS {
        let tier = Arc::clone(&tier);
        let probe = probe.clone();
        clients.push(std::thread::spawn(move || {
            let ticket = Ticket::new();
            let mut seen = Vec::with_capacity(ITERS);
            for _ in 0..ITERS {
                tier.submit(client, probe.clone(), Arc::clone(&ticket))
                    .unwrap();
                seen.push(ticket.wait());
            }
            seen
        }));
    }

    // Publish the remaining nine models while the clients hammer away.
    for m in &models[1..] {
        let v = tier
            .publish(m.clone())
            .unwrap()
            .expect("distinct model must swap, not skip");
        expect.insert(v, m.predict(&probe).to_bits());
        std::thread::sleep(Duration::from_millis(2));
    }

    // The joins themselves are the no-stall check: every client finishes
    // its fixed workload despite the concurrent swap churn.
    let mut total = 0u64;
    for handle in clients {
        for (score, version) in handle.join().unwrap() {
            let want = expect
                .get(&version)
                .unwrap_or_else(|| panic!("score attributed to unpublished version {version}"));
            assert_eq!(
                score.to_bits(),
                *want,
                "torn model: score does not match snapshot v{version}"
            );
            total += 1;
        }
    }
    assert_eq!(total, CLIENTS * ITERS as u64);

    let tier = Arc::try_unwrap(tier).unwrap_or_else(|_| panic!("tier still referenced"));
    let report = tier.shutdown().unwrap();
    assert_eq!(report.served, total);
    assert_eq!(report.latency.count, total);
    assert_eq!(report.swaps, 9);
    assert_eq!(report.skipped_repads, 0);
    assert!(report.queue_high_water >= 1);
    assert!(report.latency.max_ns >= report.latency.p50_ns);
}

#[test]
fn scores_are_bitwise_shard_count_invariant() {
    const QUERIES: usize = 400;
    let model = seeded_model(11, 48, 6, 0.25);
    let mut rng = Pcg64::new(99, 5);
    let queries: Vec<Vec<f64>> = (0..QUERIES)
        .map(|_| (0..model.dim).map(|_| rng.uniform(-1.0, 1.0)).collect())
        .collect();
    let serial = model.predict_batch(&queries);

    // The single-shard facade's native path is the same computation.
    let mut svc = PredictionService::new(None, model.clone(), 0.25).unwrap();
    let (facade, path) = svc.score_batch(&queries).unwrap();
    assert_eq!(path, ScorePath::Native);
    for (i, (a, b)) in serial.iter().zip(&facade).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "facade differs at query {i}");
    }

    for shards in [1usize, 2, 4] {
        let cfg = ServingConfig {
            shards,
            batch: 8,
            ..ServingConfig::default()
        };
        let tier = ServingTier::start(model.clone(), &cfg);
        let tickets: Vec<Arc<Ticket>> = (0..QUERIES).map(|_| Ticket::new()).collect();
        for (i, q) in queries.iter().enumerate() {
            // client_id = query index: round-robins queries across shards,
            // so every shard count partitions the batch differently.
            tier.submit(i as u64, q.clone(), Arc::clone(&tickets[i]))
                .unwrap();
        }
        for (i, ticket) in tickets.iter().enumerate() {
            let (score, version) = ticket.wait();
            assert_eq!(version, 1);
            assert_eq!(
                score.to_bits(),
                serial[i].to_bits(),
                "shards={shards}: query {i} diverged from serial predict_batch"
            );
        }
        let report = tier.shutdown().unwrap();
        assert_eq!(report.shards, shards);
        assert_eq!(report.served, QUERIES as u64);
        assert_eq!(report.latency.count, QUERIES as u64);
        assert!(report.queue_high_water >= 1);
    }
}
