//! Property tests on the protocol's core invariants, driven by the
//! in-repo property harness (`kdol::testing`).

use kdol::compression::Compressor;
use kdol::kernel::{Kernel, Model, SvModel};
use kdol::protocol::configuration_divergence;
use kdol::protocol::sync::synchronize;
use kdol::testing::{check, default_cases, gen};
use kdol::util::Rng;

fn rbf() -> Kernel {
    Kernel::Rbf { gamma: 0.5 }
}

#[test]
fn prop_average_is_mean_of_predictions() {
    // Prop. 2: the dual-form average evaluates to the pointwise mean of
    // the member models, everywhere.
    check("avg-pointwise", default_cases(), |rng| {
        let m = gen::int(rng, 2, 5);
        let dim = gen::int(rng, 1, 4);
        let models: Vec<Model> = (0..m)
            .map(|i| {
                let n = gen::int(rng, 0, 8);
                Model::Kernel(gen::sv_model(rng, rbf(), n, dim, (i as u64 + 1) << 32))
            })
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let avg = Model::average(&refs);
        for _ in 0..5 {
            let x = gen::vector(rng, dim, 1.5);
            let mean: f64 =
                models.iter().map(|f| f.predict(&x)).sum::<f64>() / m as f64;
            assert!(
                (avg.predict(&x) - mean).abs() < 1e-9,
                "avg {} vs mean {}",
                avg.predict(&x),
                mean
            );
        }
    });
}

#[test]
fn prop_divergence_zero_iff_equal_configuration() {
    check("div-zero", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 1, 6);
        let f = gen::sv_model(rng, rbf(), n, dim, 7);
        let m = gen::int(rng, 2, 5);
        let models: Vec<Model> = (0..m).map(|_| Model::Kernel(f.clone())).collect();
        let refs: Vec<&Model> = models.iter().collect();
        let d = configuration_divergence(&refs);
        assert!(d.delta < 1e-12, "equal configuration diverged: {}", d.delta);
    });
}

#[test]
fn prop_divergence_nonnegative() {
    check("div-nonneg", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let m = gen::int(rng, 2, 5);
        let models: Vec<Model> = (0..m)
            .map(|i| {
                let n = gen::int(rng, 0, 6);
                Model::Kernel(gen::sv_model(rng, rbf(), n, dim, (i as u64 + 1) << 20))
            })
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let d = configuration_divergence(&refs);
        assert!(d.delta >= -1e-12);
        for v in d.per_learner {
            assert!(v >= -1e-12);
        }
    });
}

#[test]
fn prop_averaging_is_contractive() {
    // After replacing every model by the average, divergence is 0 and each
    // learner's distance to any fixed reference shrinks on average
    // (variance decomposition).
    check("avg-contracts", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let m = gen::int(rng, 2, 4);
        let models: Vec<Model> = (0..m)
            .map(|i| {
                let n = gen::int(rng, 1, 5);
                Model::Kernel(gen::sv_model(rng, rbf(), n, dim, (i as u64 + 1) << 20))
            })
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let before = configuration_divergence(&refs).delta;
        let (avg, _) = synchronize(&refs, Compressor::None);
        let synced: Vec<Model> = (0..m).map(|_| avg.clone()).collect();
        let srefs: Vec<&Model> = synced.iter().collect();
        let after = configuration_divergence(&srefs).delta;
        assert!(after < 1e-10);
        assert!(after <= before + 1e-12);
    });
}

#[test]
fn prop_compression_error_matches_reported() {
    // The compressor's reported eps upper-bounds the true RKHS
    // perturbation (triangle inequality across steps; exact per step).
    check("comp-eps", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let n = gen::int(rng, 4, 12);
        let tau = gen::int(rng, 1, 3);
        let model = gen::sv_model(rng, rbf(), n, dim, 50);
        for comp in [
            Compressor::Truncation { tau },
            Compressor::Projection { tau },
        ] {
            let mut c = model.clone();
            let out = comp.compress(&mut c);
            let true_err = c.distance_sq(&model).sqrt();
            assert!(
                true_err <= out.err + 1e-6,
                "true {true_err} > reported {}",
                out.err
            );
            assert!(c.len() <= tau);
        }
    });
}

#[test]
fn prop_distance_is_a_metric_ish() {
    // Symmetry and the triangle inequality for the RKHS distance.
    check("metric", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let (na, nb, nc) = (
            gen::int(rng, 0, 5),
            gen::int(rng, 0, 5),
            gen::int(rng, 0, 5),
        );
        let a = gen::sv_model(rng, rbf(), na, dim, 1 << 10);
        let b = gen::sv_model(rng, rbf(), nb, dim, 2 << 10);
        let c = gen::sv_model(rng, rbf(), nc, dim, 3 << 10);
        let dab = a.distance_sq(&b).sqrt();
        let dba = b.distance_sq(&a).sqrt();
        assert!((dab - dba).abs() < 1e-9);
        let dac = a.distance_sq(&c).sqrt();
        let dcb = c.distance_sq(&b).sqrt();
        assert!(dab <= dac + dcb + 1e-9, "triangle: {dab} > {dac} + {dcb}");
    });
}

#[test]
fn prop_wire_roundtrip_arbitrary_messages() {
    use kdol::network::{Message, SvBlock};
    use kdol::ser::{from_bytes, to_bytes};
    check("wire-roundtrip", default_cases(), |rng| {
        let n = gen::int(rng, 0, 20);
        let dim = gen::int(rng, 1, 8);
        let coeffs: Vec<(u64, f64)> = (0..n).map(|i| (i as u64, rng.normal())).collect();
        let k = gen::int(rng, 0, n.max(1));
        let block = SvBlock {
            ids: (0..k as u64).collect(),
            dim: dim as u32,
            coords: (0..k * dim).map(|_| rng.normal() as f32).collect(),
        };
        let msg = Message::ModelUpload {
            learner: gen::int(rng, 0, 31) as u32,
            round: rng.next_u64() % 10_000,
            coeffs,
            new_svs: block,
        };
        let bytes = to_bytes(&msg).unwrap();
        assert_eq!(bytes.len(), msg.wire_bytes());
        let back: Message = from_bytes(&bytes).unwrap();
        assert_eq!(back, msg);
    });
}

#[test]
fn prop_toml_numbers_roundtrip() {
    use kdol::config::parse_toml;
    check("toml-numbers", default_cases(), |rng| {
        let i = rng.next_u64() as i64 / 2;
        let f = rng.normal() * 1e3;
        let doc = format!("a = {i}\nb = {f:e}\n");
        let t = parse_toml(&doc).unwrap();
        assert_eq!(t["a"].as_int(), Some(i));
        let fb = t["b"].as_float().unwrap();
        assert!((fb - f).abs() <= 1e-9 * f.abs().max(1.0));
    });
}

#[test]
fn prop_sv_model_incremental_ops_consistent() {
    // push/swap_remove/scale keep predict() consistent with a naive model.
    check("svmodel-ops", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let mut model = SvModel::new(rbf(), dim);
        let mut naive: Vec<(Vec<f64>, f64)> = Vec::new();
        for step in 0..20 {
            match gen::int(rng, 0, 2) {
                0 => {
                    let x = gen::vector(rng, dim, 1.0);
                    let a = rng.normal();
                    model.push(step as u64, &x, a);
                    naive.push((x, a));
                }
                1 if !naive.is_empty() => {
                    let i = gen::int(rng, 0, naive.len() - 1);
                    model.swap_remove(i);
                    naive.swap_remove(i);
                }
                _ => {
                    model.scale(0.9);
                    for (_, a) in naive.iter_mut() {
                        *a *= 0.9;
                    }
                }
            }
            let x = gen::vector(rng, dim, 1.0);
            let want: f64 = naive
                .iter()
                .map(|(s, a)| a * rbf().eval(s, &x))
                .sum();
            assert!((model.predict(&x) - want).abs() < 1e-9);
        }
    });
}
