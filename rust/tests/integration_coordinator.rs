//! Integration tests of the threaded leader/worker cluster runtime: the
//! deployable topology must reproduce the engine's qualitative behaviour
//! over real (serialized, channel-crossing) messages.

use kdol::config::{ExperimentConfig, KernelConfig, ProtocolConfig};
use kdol::coordinator::run_cluster;

fn cfg(protocol: ProtocolConfig) -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.learners = 3;
    c.rounds = 60;
    c.protocol = protocol;
    c.name = format!("cluster-{}", protocol.label());
    c
}

#[test]
fn cluster_runs_periodic_kernel() {
    let out = run_cluster(&cfg(ProtocolConfig::Periodic { period: 10 })).unwrap();
    assert!(out.cum_loss > 0.0);
    assert!(out.comm.total_bytes() > 0);
    assert!(out.comm.syncs >= 5, "syncs {}", out.comm.syncs);
    assert!(out.final_model.is_some());
}

#[test]
fn cluster_runs_dynamic_kernel() {
    let out = run_cluster(&cfg(ProtocolConfig::Dynamic {
        delta: 0.2,
        check_period: 1,
    }))
    .unwrap();
    // Dynamic: some violations should have occurred on this task, and the
    // cluster must shut down cleanly either way.
    assert!(out.cum_loss > 0.0);
    if out.comm.syncs > 0 {
        assert!(out.comm.total_bytes() > 0);
        assert!(out.final_model.is_some());
    }
}

#[test]
fn cluster_runs_dynamic_kernel_with_partial_sync() {
    let mut c = cfg(ProtocolConfig::Dynamic {
        delta: 0.2,
        check_period: 1,
    });
    c.partial_sync = true;
    c.learners = 4;
    let out = run_cluster(&c).unwrap();
    assert!(out.cum_loss > 0.0);
    // Partial balancing never *adds* global syncs; whatever happened the
    // run must shut down cleanly with coherent accounting.
    assert_eq!(out.rounds, 60);
    if out.partial_syncs > 0 {
        assert!(out.comm.total_bytes() > 0);
    }
}

#[test]
fn cluster_runs_linear_models() {
    let mut c = cfg(ProtocolConfig::Periodic { period: 5 });
    c.learner.kernel = KernelConfig::Linear;
    c.learner.compression = kdol::config::CompressionConfig::None;
    let out = run_cluster(&c).unwrap();
    assert!(out.comm.syncs >= 10);
    assert!(out.final_model.unwrap().as_linear().is_some());
}

#[test]
fn cluster_nosync_communicates_nothing() {
    let out = run_cluster(&cfg(ProtocolConfig::NoSync)).unwrap();
    assert_eq!(out.comm.syncs, 0);
    // Done/Shutdown are runtime control, not protocol communication:
    // like the engine, a NoSync cluster reports zero bytes and messages.
    assert_eq!(out.comm.total_bytes(), 0);
    assert_eq!(out.comm.total_msgs(), 0);
}

#[test]
fn cluster_loss_comparable_to_engine() {
    // Thread interleaving changes sync timing for dynamic protocols, but a
    // scheduled (periodic) cluster must match the engine's cumulative loss
    // closely: same streams, same update rule, same sync schedule.
    let c = cfg(ProtocolConfig::Periodic { period: 10 });
    let cluster = run_cluster(&c).unwrap();
    let engine = kdol::experiments::run_experiment(&c).unwrap();
    let rel = (cluster.cum_loss - engine.cumulative_loss).abs()
        / engine.cumulative_loss.max(1e-9);
    assert!(
        rel < 0.35,
        "cluster loss {} vs engine {} (rel {rel})",
        cluster.cum_loss,
        engine.cumulative_loss
    );
}
