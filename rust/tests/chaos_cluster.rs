//! Chaos conformance suite: the threaded cluster under seeded fault
//! injection and planned churn.
//!
//! The contract, scenario by scenario:
//!
//! * **Benign schedules** (delay-only, duplicate-only on the upstream
//!   links) are absorbed by flush ordering and duplicate suppression:
//!   lockstep fixed-size runs must keep **exact** engine parity — same
//!   syncs, violations, bytes, messages — with the fault machinery
//!   provably exercised (`faults_injected > 0`, retries zero).
//! * **Lossy schedules** (drops, and the all-faults combination) must
//!   terminate through the leader's retry ladders, and a same-seed rerun
//!   must replay **bitwise**: identical robustness counters, byte
//!   counts, quarantine evidence, and cumulative loss. The fault
//!   sequence is a pure function of `(seed, link, dir, frame index)`
//!   and lockstep pins the frame order, so chaos runs are reproducible.
//! * **A misbehaving worker** (every upload bit-corrupted) is
//!   quarantined with recorded evidence, and the surviving cluster's
//!   communication stays loss-proportional (the paper's efficiency
//!   criterion, evaluated exactly as in `e2e_loss_proportionality`).
//! * **Planned churn** (workers with `join..=leave` windows) runs clean:
//!   no retries, no quarantine, deterministic across reruns.

use kdol::config::{
    CompressionConfig, DataConfig, ExperimentConfig, KernelConfig, ProtocolConfig,
};
use kdol::coordinator::{run_cluster, ClusterOutcome};
use kdol::experiments::run_experiment;
use kdol::metrics::{EfficiencyReport, Outcome};
use kdol::network::{ChurnEntry, CommStats, FaultPlanConfig, LinkFaultConfig, RobustnessStats};

/// Base dynamic drift scenario (fixed-size model, lockstep) — the same
/// shape as the parity suite's conformance matrix, shortened to keep the
/// retry-deadline cost of lossy schedules bounded.
fn chaos_cfg(label: &str, delta: f64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.name = format!("chaos-{label}-delta{delta}");
    c.seed = 7;
    c.learners = 4;
    c.rounds = 60;
    c.data = DataConfig::Hyperplane {
        dim: 8,
        drift: 0.05,
    };
    c.learner.kernel = KernelConfig::Linear;
    c.learner.compression = CompressionConfig::None;
    c.learner.eta = 0.1;
    c.protocol = ProtocolConfig::Dynamic {
        delta,
        check_period: 1,
    };
    c.partial_sync = true;
    c.lockstep = true;
    c.recv_timeout_ms = 500;
    c.max_retries = 3;
    c
}

/// Pick a threshold whose clean engine run produces between `lo` and
/// `hi` resolution events: enough traffic for the fault plan to bite,
/// few enough that per-drop retry deadlines keep the test fast.
fn pick_eventful(label: &str, partial: bool, lo: u64, hi: u64) -> ExperimentConfig {
    for &delta in &[0.2, 0.1, 0.05, 0.02, 0.01] {
        let mut c = chaos_cfg(label, delta);
        c.partial_sync = partial;
        let engine = run_experiment(&c).unwrap();
        let events = engine.comm.syncs + engine.partial_syncs;
        if (lo..=hi).contains(&events) {
            return c;
        }
    }
    panic!("{label}: no delta in the sweep produced {lo}..={hi} events");
}

fn up_only(seed: u64, up: LinkFaultConfig) -> FaultPlanConfig {
    FaultPlanConfig {
        seed,
        up,
        down: LinkFaultConfig::default(),
        workers: None,
    }
}

/// Internal-consistency invariants every outcome must satisfy.
fn assert_consistent(out: &ClusterOutcome) {
    assert_eq!(
        out.robustness.quarantined as usize,
        out.quarantine.len(),
        "quarantine counter disagrees with the evidence list"
    );
    assert!(out.cum_loss.is_finite(), "non-finite cumulative loss");
}

fn assert_comm_eq(a: &CommStats, b: &CommStats, what: &str) {
    assert_eq!(a.syncs, b.syncs, "{what}: syncs");
    assert_eq!(a.violations, b.violations, "{what}: violations");
    assert_eq!(a.up_bytes, b.up_bytes, "{what}: up bytes");
    assert_eq!(a.down_bytes, b.down_bytes, "{what}: down bytes");
    assert_eq!(a.up_msgs, b.up_msgs, "{what}: up messages");
    assert_eq!(a.down_msgs, b.down_msgs, "{what}: down messages");
    assert_eq!(a.last_sync_round, b.last_sync_round, "{what}: last sync round");
    assert_eq!(
        a.peak_round_bytes, b.peak_round_bytes,
        "{what}: peak round bytes"
    );
}

/// Exact engine parity for a benign fault schedule: the clean engine run
/// of the same config is the reference trajectory.
fn assert_benign_parity(cfg: &ExperimentConfig) -> ClusterOutcome {
    let mut clean = cfg.clone();
    clean.faults = None;
    let engine = run_experiment(&clean).unwrap();
    assert!(
        engine.comm.syncs + engine.partial_syncs > 0,
        "{}: scenario never communicates — parity would be vacuous",
        cfg.name
    );
    let cluster = run_cluster(cfg).unwrap();
    assert_consistent(&cluster);
    assert!(
        cluster.robustness.faults_injected > 0,
        "{}: the fault plan never fired — benign parity untested",
        cfg.name
    );
    assert_comm_eq(&engine.comm, &cluster.comm, &cfg.name);
    assert_eq!(
        engine.partial_syncs, cluster.partial_syncs,
        "{}: partial syncs",
        cfg.name
    );
    assert_eq!(cluster.robustness.retries, 0, "{}: benign retries", cfg.name);
    assert!(cluster.quarantine.is_empty(), "{}: benign quarantine", cfg.name);
    let rel = (engine.cumulative_loss - cluster.cum_loss).abs()
        / engine.cumulative_loss.abs().max(1e-9);
    assert!(
        rel < 1e-9,
        "{}: engine loss {} vs cluster {}",
        cfg.name,
        engine.cumulative_loss,
        cluster.cum_loss
    );
    cluster
}

#[test]
fn benign_delay_schedule_keeps_exact_engine_parity() {
    // Held frames flush before any control barrier and within every
    // receive poll slice, so delays reorder nothing the protocol can
    // observe: the trajectory and every byte count match the engine.
    let mut cfg = pick_eventful("delay", true, 3, 40);
    cfg.faults = Some(up_only(
        5,
        LinkFaultConfig {
            delay: 0.35,
            delay_polls: 2,
            ..LinkFaultConfig::default()
        },
    ));
    cfg.validate().unwrap();
    let out = assert_benign_parity(&cfg);
    assert_eq!(out.robustness.dup_suppressed, 0);
    assert_eq!(out.robustness.stale_suppressed, 0);
}

#[test]
fn benign_duplicate_schedule_keeps_exact_engine_parity() {
    // Every duplicated violation / report / upload is suppressed before
    // it can be double-ingested or double-counted, so the engine's
    // trajectory and byte counts survive untouched.
    let mut cfg = pick_eventful("duplicate", true, 3, 40);
    cfg.faults = Some(up_only(
        5,
        LinkFaultConfig {
            duplicate: 0.5,
            ..LinkFaultConfig::default()
        },
    ));
    cfg.validate().unwrap();
    let out = assert_benign_parity(&cfg);
    assert!(
        out.robustness.dup_suppressed + out.robustness.stale_suppressed > 0,
        "duplicates were injected but never suppressed"
    );
}

#[test]
fn drop_schedule_terminates_and_replays_bitwise() {
    // Drops on both directions force the retry ladders; the run must
    // terminate and a same-seed rerun must replay every counter exactly.
    let mut cfg = pick_eventful("drop", true, 4, 20);
    cfg.recv_timeout_ms = 250;
    cfg.faults = Some(FaultPlanConfig {
        seed: 11,
        up: LinkFaultConfig {
            drop: 0.15,
            ..LinkFaultConfig::default()
        },
        down: LinkFaultConfig {
            drop: 0.1,
            ..LinkFaultConfig::default()
        },
        workers: None,
    });
    cfg.validate().unwrap();
    let a = run_cluster(&cfg).unwrap();
    let b = run_cluster(&cfg).unwrap();
    for out in [&a, &b] {
        assert_consistent(out);
        assert_eq!(out.rounds, cfg.rounds as u64);
    }
    assert!(a.robustness.faults_injected > 0, "drop plan never fired");
    assert_eq!(a.robustness, b.robustness, "robustness counters replay");
    assert_eq!(a.quarantine, b.quarantine, "quarantine evidence replays");
    assert_comm_eq(&a.comm, &b.comm, "drop rerun");
    assert_eq!(a.partial_syncs, b.partial_syncs);
    assert_eq!(a.cum_loss.to_bits(), b.cum_loss.to_bits(), "loss replays bitwise");
}

#[test]
fn combined_chaos_schedule_terminates_and_replays_bitwise() {
    // Everything at once — loss, delay, duplication, reordering, and a
    // sliver of corruption on both directions. The only promises are
    // termination and bitwise reproducibility under the same seed.
    let mut cfg = pick_eventful("combined", true, 4, 20);
    cfg.recv_timeout_ms = 250;
    cfg.faults = Some(FaultPlanConfig {
        seed: 23,
        up: LinkFaultConfig {
            drop: 0.08,
            delay: 0.1,
            delay_polls: 2,
            duplicate: 0.1,
            reorder: 0.08,
            corrupt: 0.04,
        },
        down: LinkFaultConfig {
            drop: 0.06,
            duplicate: 0.06,
            reorder: 0.05,
            corrupt: 0.04,
            ..LinkFaultConfig::default()
        },
        workers: None,
    });
    cfg.validate().unwrap();
    let a = run_cluster(&cfg).unwrap();
    let b = run_cluster(&cfg).unwrap();
    for out in [&a, &b] {
        assert_consistent(out);
        assert_eq!(out.rounds, cfg.rounds as u64);
    }
    assert!(a.robustness.faults_injected > 0, "chaos plan never fired");
    assert_eq!(a.robustness, b.robustness, "robustness counters replay");
    assert_eq!(a.quarantine, b.quarantine, "quarantine evidence replays");
    assert_comm_eq(&a.comm, &b.comm, "chaos rerun");
    assert_eq!(a.partial_syncs, b.partial_syncs);
    assert_eq!(a.cum_loss.to_bits(), b.cum_loss.to_bits(), "loss replays bitwise");
}

#[test]
fn corrupt_worker_is_quarantined_and_survivors_stay_loss_proportional() {
    // Worker 2's every upstream protocol frame is bit-corrupted — the
    // "provably misbehaving" node. Corruption flips the tag byte, so its
    // frames are undecodable on arrival: the leader must quarantine it
    // with that evidence and finish the run over the survivors, whose
    // communication still satisfies the paper's loss-proportionality
    // criterion (same PA setup and ETA_C as `e2e_loss_proportionality`;
    // pure protocol — the per-event bound argument needs full syncs).
    const ETA_C: f64 = 2.0;
    let delta = 0.2;
    let mut cfg = ExperimentConfig::quickstart();
    cfg.name = "chaos-corrupt-worker".into();
    cfg.seed = 13;
    cfg.learners = 4;
    cfg.rounds = 200;
    cfg.data = DataConfig::Hyperplane {
        dim: 8,
        drift: 0.05,
    };
    cfg.learner.kernel = KernelConfig::Linear;
    cfg.learner.compression = CompressionConfig::None;
    cfg.learner.eta = 0.3; // PA cap C
    cfg.learner.passive_aggressive = true;
    cfg.protocol = ProtocolConfig::Dynamic {
        delta,
        check_period: 1,
    };
    cfg.partial_sync = false;
    cfg.lockstep = true;
    cfg.recv_timeout_ms = 500;
    cfg.max_retries = 2;
    cfg.faults = Some(FaultPlanConfig {
        seed: 13,
        up: LinkFaultConfig {
            corrupt: 1.0,
            ..LinkFaultConfig::default()
        },
        down: LinkFaultConfig::default(),
        workers: Some(vec![2]),
    });
    cfg.validate().unwrap();

    let out = run_cluster(&cfg).unwrap();
    assert_consistent(&out);
    assert_eq!(
        out.quarantine.len(),
        1,
        "exactly the corrupted worker is quarantined: {:?}",
        out.quarantine
    );
    assert_eq!(out.quarantine[0].learner, 2, "wrong offender");
    assert!(
        out.quarantine[0].reason.contains("undecodable"),
        "evidence should name the decode failure, got: {}",
        out.quarantine[0].reason
    );
    assert!(
        out.comm.syncs > 0,
        "survivors never synchronized — the bound check would be vacuous"
    );

    // Survivor efficiency: evaluate the loss-form Prop. 6 bound and the
    // fixed-size communication bound on the cluster outcome.
    let measured = Outcome {
        name: cfg.name.clone(),
        learners: cfg.learners,
        rounds: out.rounds,
        cumulative_loss: out.cum_loss,
        cumulative_error: out.cum_error,
        cum_drift: 0.0, // unknown cluster-side; the drift-form check is skipped
        cum_compression_err: out.cum_compression_err,
        comm: out.comm.clone(),
        partial_syncs: out.partial_syncs,
        sync_cache: Default::default(),
        series: vec![],
        mean_svs: 0.0,
        wall_secs: 0.0,
    };
    let rep = EfficiencyReport::evaluate(&measured, ETA_C, delta, 0, cfg.data.dim(), None);
    let loss_form = rep
        .checks
        .iter()
        .find(|c| c.name == "Prop6 events <= eta*L/sqrt(Delta)")
        .expect("loss-form Prop6 check missing");
    assert!(
        loss_form.holds(),
        "survivor events {} exceed the loss-proportional bound {}",
        loss_form.measured,
        loss_form.bound
    );
    let comm = rep
        .checks
        .iter()
        .find(|c| c.name == "comm bound (fixed-size)")
        .expect("fixed-size communication bound check missing");
    assert!(
        comm.holds(),
        "survivor bytes {} exceed the loss-proportional communication bound {}",
        comm.measured,
        comm.bound
    );
}

#[test]
fn planned_churn_runs_clean_and_replays_bitwise() {
    // Membership windows on a clean bus: a late joiner and an early
    // leaver. No fault machinery may fire — churn is planned, not a
    // failure — and the lockstep trajectory is deterministic.
    let mut cfg = chaos_cfg("churn", 0.1);
    cfg.churn = vec![
        ChurnEntry {
            worker: 1,
            join: 5,
            leave: 40,
        },
        ChurnEntry {
            worker: 3,
            join: 20,
            leave: 60,
        },
    ];
    cfg.validate().unwrap();
    let a = run_cluster(&cfg).unwrap();
    let b = run_cluster(&cfg).unwrap();
    for out in [&a, &b] {
        assert_consistent(out);
        assert_eq!(out.rounds, cfg.rounds as u64);
        assert_eq!(
            out.robustness,
            RobustnessStats::default(),
            "planned churn must not trip the fault machinery"
        );
        assert!(out.quarantine.is_empty());
        assert!(out.cum_loss > 0.0, "joined workers never played");
    }
    assert_comm_eq(&a.comm, &b.comm, "churn rerun");
    assert_eq!(a.partial_syncs, b.partial_syncs);
    assert_eq!(a.cum_loss.to_bits(), b.cum_loss.to_bits(), "loss replays bitwise");
}

#[test]
fn free_running_drop_schedule_terminates() {
    // No lockstep barrier to lean on: free-running workers under
    // upstream loss. Dropped violations are simply lost events; dropped
    // uploads ride the retry ladder. The run must still complete the
    // full horizon with internally consistent accounting.
    let mut cfg = pick_eventful("free", false, 1, 20);
    cfg.lockstep = false;
    cfg.recv_timeout_ms = 250;
    cfg.max_retries = 2;
    cfg.faults = Some(up_only(
        31,
        LinkFaultConfig {
            drop: 0.2,
            ..LinkFaultConfig::default()
        },
    ));
    cfg.validate().unwrap();
    let out = run_cluster(&cfg).unwrap();
    assert_consistent(&out);
    assert_eq!(out.rounds, cfg.rounds as u64);
}
