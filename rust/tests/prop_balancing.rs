//! Property tests pinning fixed-size subset balancing against naive
//! oracles: the farthest-first growth order, the `||avg_B - r||^2 <=
//! Delta` safe-zone decision, the full grow-until-safe loop, and mean
//! preservation over the balancing set after the download — across
//! randomized weight vectors and thresholds. Run with
//! `KDOL_PROP_CASES=256` (the scheduled deep CI job does) for the wide
//! matrix.

use kdol::kernel::{LinearModel, Model};
use kdol::protocol::balancing::{fixed_dist_sq, BalanceGeometry, BalancingSet, FixedGeometry};
use kdol::testing::{check, default_cases, gen};
use kdol::util::float::sq_dist;
use kdol::util::{par, Pcg64, Rng};

/// Random distance vector with deliberate exact ties.
fn distances(rng: &mut Pcg64, m: usize) -> Vec<f64> {
    let mut d: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
    // Duplicate a value with some probability to exercise tie-breaking.
    if m >= 2 && rng.f64() < 0.5 {
        let a = gen::int(rng, 0, m - 1);
        let b = gen::int(rng, 0, m - 1);
        d[a] = d[b];
    }
    d
}

/// Random non-empty strict subset of 0..m (ascending — the order the
/// engine discovers same-round violators in).
fn violator_set(rng: &mut Pcg64, m: usize) -> Vec<usize> {
    loop {
        let v: Vec<usize> = (0..m).filter(|_| rng.f64() < 0.4).collect();
        if !v.is_empty() && v.len() < m {
            return v;
        }
    }
}

/// Oracle: repeatedly pick the farthest non-member, ties to the higher
/// learner index (independent re-derivation of the documented order).
fn oracle_extension(m: usize, violators: &[usize], d: &[f64]) -> Vec<usize> {
    let mut picked = vec![false; m];
    for &v in violators {
        picked[v] = true;
    }
    let mut order = Vec::new();
    loop {
        let mut best: Option<usize> = None;
        for i in 0..m {
            if picked[i] {
                continue;
            }
            best = match best {
                None => Some(i),
                Some(j) if d[i] >= d[j] => Some(i),
                keep => keep,
            };
        }
        match best {
            Some(i) => {
                picked[i] = true;
                order.push(i);
            }
            None => return order,
        }
    }
}

#[test]
fn prop_farthest_first_order_matches_oracle() {
    check("balancing-order", default_cases(), |rng| {
        let m = gen::int(rng, 2, 10);
        let violators = violator_set(rng, m);
        let d = distances(rng, m);
        let mut set = BalancingSet::new(m, &violators, &d);
        assert_eq!(set.members(), &violators[..], "seed must be the violators");
        let mut got = Vec::new();
        while let Some(next) = set.extend() {
            got.push(next);
        }
        assert!(set.is_full());
        assert_eq!(
            got,
            oracle_extension(m, &violators, &d),
            "extension order diverged (m={m}, violators={violators:?}, d={d:?})"
        );
    });
}

/// Naive elementwise mean of the members' weight vectors.
fn naive_mean(ws: &[&[f64]]) -> Vec<f64> {
    let dim = ws[0].len();
    let mut out = vec![0.0; dim];
    for w in ws {
        for (o, &v) in out.iter_mut().zip(w.iter()) {
            *o += v;
        }
    }
    for o in &mut out {
        *o /= ws.len() as f64;
    }
    out
}

#[test]
fn prop_safe_zone_decision_matches_naive_oracle() {
    check("balancing-safe-zone", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 20);
        let n = gen::int(rng, 1, 6);
        let models: Vec<Model> = (0..n)
            .map(|_| Model::Linear(LinearModel::from_w(gen::vector(rng, dim, 1.0))))
            .collect();
        let has_ref = rng.f64() < 0.8;
        let reference = has_ref.then(|| LinearModel::from_w(gen::vector(rng, dim, 1.0)));
        let mut geom = FixedGeometry::new(reference.as_ref());

        let refs: Vec<&Model> = models.iter().collect();
        let avg = Model::average(&refs);
        let module_dist = geom.dist_to_reference(&avg);

        let ws: Vec<&[f64]> = models
            .iter()
            .map(|m| m.as_linear().unwrap().w.as_slice())
            .collect();
        let mean = naive_mean(&ws);
        let zero = vec![0.0; dim];
        let r = reference.as_ref().map(|r| r.w.as_slice()).unwrap_or(&zero);
        let naive: f64 = mean
            .iter()
            .zip(r)
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum();

        assert!(
            (module_dist - naive).abs() <= 1e-12 * naive.max(1.0),
            "module {module_dist} vs naive {naive}"
        );
        // The decision agrees for every threshold away from the
        // floating-point boundary.
        for _ in 0..4 {
            let delta = rng.uniform(0.0, 2.0 * naive.max(0.1));
            if (naive - delta).abs() <= 1e-9 * naive.max(1.0) {
                continue;
            }
            assert_eq!(module_dist <= delta, naive <= delta, "delta {delta}");
        }
    });
}

#[test]
fn prop_grow_until_safe_matches_oracle() {
    // The composite behavior: grow B farthest-first until the B-average
    // re-enters the safe zone, escalate when B would cover the cluster.
    check("balancing-loop", default_cases(), |rng| {
        let m = gen::int(rng, 2, 7);
        let dim = gen::int(rng, 1, 10);
        let ws: Vec<Vec<f64>> = (0..m).map(|_| gen::vector(rng, dim, 1.0)).collect();
        let reference = LinearModel::from_w(gen::vector(rng, dim, 0.3));
        let violators = violator_set(rng, m);
        let d: Vec<f64> = ws.iter().map(|w| sq_dist(w, &reference.w)).collect();
        let delta = rng.uniform(0.05, 1.5);

        // Oracle: smallest k such that the mean over (violators + the k
        // farthest others, by the oracle order) is within delta of r;
        // escalation when only the full cluster (or nothing) would do.
        let ext = oracle_extension(m, &violators, &d);
        let mut oracle_members: Option<Vec<usize>> = None;
        let mut near_boundary = false;
        for k in 0..ext.len() {
            // B never grows to the whole cluster: the algorithm escalates
            // instead of testing a full B.
            let mut members = violators.clone();
            members.extend_from_slice(&ext[..k]);
            let sel: Vec<&[f64]> = members.iter().map(|&i| ws[i].as_slice()).collect();
            let mean = naive_mean(&sel);
            let dist = sq_dist(&mean, &reference.w);
            if (dist - delta).abs() <= 1e-9 * delta.max(1.0) {
                near_boundary = true;
                break;
            }
            if dist <= delta {
                oracle_members = Some(members);
                break;
            }
        }
        if near_boundary {
            return; // ambiguous at f64 resolution — skip the case
        }

        // Module: the loop exactly as the engine/leader run it.
        let mut geom = FixedGeometry::new(Some(&reference));
        let mut set = BalancingSet::new(m, &violators, &d);
        let module_members: Option<Vec<usize>> = loop {
            if set.is_full() {
                break None;
            }
            let models: Vec<Model> = set
                .members()
                .iter()
                .map(|&i| Model::Linear(LinearModel::from_w(ws[i].clone())))
                .collect();
            let refs: Vec<&Model> = models.iter().collect();
            let avg = Model::average(&refs);
            if geom.dist_to_reference(&avg) <= delta {
                break Some(set.members().to_vec());
            }
            if set.extend().is_none() {
                break None;
            }
        };

        assert_eq!(
            module_members, oracle_members,
            "m={m}, violators={violators:?}, delta={delta}"
        );
    });
}

#[test]
fn prop_download_preserves_balancing_set_mean() {
    check("balancing-mean-preserved", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 16);
        let n = gen::int(rng, 1, 6);
        let before: Vec<Vec<f64>> = (0..n).map(|_| gen::vector(rng, dim, 1.0)).collect();
        let models: Vec<Model> = before
            .iter()
            .map(|w| Model::Linear(LinearModel::from_w(w.clone())))
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        let avg = Model::average(&refs);
        let avg_w = &avg.as_linear().unwrap().w;

        // Every member adopts avg_B; the mean over the balancing set is
        // unchanged (that is exactly why the rest of the cluster's
        // safe-zone proofs survive a partial synchronization).
        let after: Vec<Vec<f64>> = (0..n).map(|_| avg_w.clone()).collect();
        let mean_before = naive_mean(&before.iter().map(|w| w.as_slice()).collect::<Vec<_>>());
        let mean_after = naive_mean(&after.iter().map(|w| w.as_slice()).collect::<Vec<_>>());
        for (a, b) in mean_before.iter().zip(&mean_after) {
            assert!(
                (a - b).abs() <= 1e-12 * a.abs().max(1.0),
                "mean moved: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_fixed_geometry_ignores_the_parallel_thread_knob() {
    // The fixed geometry is a fused serial sweep by design (see
    // `balancing::fixed_dist_sq` for why the parallel backend is
    // deliberately not engaged): sweeping the process-global thread knob
    // — which this test binary owns — must never change a bit of any
    // distance, even for huge RFF-scale vectors. The expectation is an
    // *independent* index-order accumulation, not sq_dist itself, so the
    // pin stays meaningful if the sweep is ever rewritten.
    let n = 50_000;
    let mut rng = Pcg64::seeded(11);
    let a = gen::vector(&mut rng, n, 1.0);
    let b = gen::vector(&mut rng, n, 1.0);
    let mut want = 0.0f64;
    for i in 0..n {
        let d = a[i] - b[i];
        want += d * d;
    }
    for t in [1usize, 2, 3, 8] {
        par::set_threads(t);
        assert_eq!(
            fixed_dist_sq(&a, &b).to_bits(),
            want.to_bits(),
            "threads={t}"
        );
    }
    par::set_threads(0);
}
