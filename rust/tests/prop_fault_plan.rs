//! Property tests pinning the fault-injection plan's determinism
//! contract: the action sequence of a link direction is a pure function
//! of `(seed, link, direction, frame index)` — bitwise reproducible
//! across calls and across threads, prefix-stable (one RNG draw per
//! offered frame, so observing fewer frames never changes the fate of
//! the ones that were offered), independent between links, and drawing
//! only actions the configuration gives positive probability. Run with
//! `KDOL_PROP_CASES=256` (the scheduled deep CI job does) for the wide
//! matrix.

use kdol::network::fault::{Dir, FaultAction, FaultPlan};
use kdol::network::{FaultPlanConfig, LinkFaultConfig};
use kdol::testing::{check, default_cases, gen};
use kdol::util::{Pcg64, Rng};

/// Random link config: probabilities drawn then scaled so their sum
/// stays in [0, 1] (the one-draw-decides-the-frame invariant).
fn link(rng: &mut Pcg64) -> LinkFaultConfig {
    let raw: Vec<f64> = (0..5).map(|_| rng.uniform(0.0, 1.0)).collect();
    let scale = rng.uniform(0.0, 1.0) / raw.iter().sum::<f64>().max(1e-12);
    // Zero out a random subset so degenerate plans (clean links, one
    // dominant fault) are covered too.
    let keep: Vec<f64> = raw
        .iter()
        .map(|&p| if rng.f64() < 0.7 { p * scale } else { 0.0 })
        .collect();
    LinkFaultConfig {
        drop: keep[0],
        delay: keep[1],
        delay_polls: gen::int(rng, 1, 8) as u32,
        duplicate: keep[2],
        reorder: keep[3],
        corrupt: keep[4],
    }
}

fn plan(rng: &mut Pcg64) -> FaultPlanConfig {
    FaultPlanConfig {
        seed: rng.below(u64::MAX),
        up: link(rng),
        down: link(rng),
        workers: None,
    }
}

fn dir(rng: &mut Pcg64) -> Dir {
    if rng.f64() < 0.5 {
        Dir::Up
    } else {
        Dir::Down
    }
}

#[test]
fn prop_trace_is_bitwise_reproducible() {
    check("trace reproducible", default_cases(), |rng| {
        let cfg = plan(rng);
        let worker = gen::int(rng, 0, 15);
        let d = dir(rng);
        let n = gen::int(rng, 1, 512);
        let a = FaultPlan::trace(&cfg, worker, d, n);
        let b = FaultPlan::trace(&cfg, worker, d, n);
        assert_eq!(a, b, "same (seed, link, dir) must replay identically");
    });
}

#[test]
fn prop_trace_is_prefix_stable() {
    // Exactly one draw per offered frame: a shorter observation window
    // is a strict prefix of a longer one, never a different sequence.
    check("trace prefix-stable", default_cases(), |rng| {
        let cfg = plan(rng);
        let worker = gen::int(rng, 0, 15);
        let d = dir(rng);
        let n = gen::int(rng, 2, 512);
        let k = gen::int(rng, 1, n - 1);
        let long = FaultPlan::trace(&cfg, worker, d, n);
        let short = FaultPlan::trace(&cfg, worker, d, k);
        assert_eq!(short.as_slice(), &long[..k]);
    });
}

#[test]
fn prop_trace_matches_incremental_draws() {
    // `trace` is exactly the sequence `next_action` produces — the bus's
    // live draws and the suite's replayed traces can never diverge.
    check("trace matches next_action", default_cases(), |rng| {
        let cfg = plan(rng);
        let worker = gen::int(rng, 0, 15);
        let d = dir(rng);
        let n = gen::int(rng, 1, 256);
        let mut live = FaultPlan::for_link(&cfg, worker, d);
        let drawn: Vec<FaultAction> = (0..n).map(|_| live.next_action()).collect();
        assert_eq!(drawn, FaultPlan::trace(&cfg, worker, d, n));
    });
}

#[test]
fn prop_trace_is_identical_across_threads() {
    // Thread scheduling must not leak into the fault sequence: the same
    // trace computed concurrently on several threads is bitwise equal.
    check("trace thread-independent", default_cases(), |rng| {
        let cfg = plan(rng);
        let worker = gen::int(rng, 0, 7);
        let d = dir(rng);
        let n = gen::int(rng, 1, 256);
        let reference = FaultPlan::trace(&cfg, worker, d, n);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let cfg = cfg.clone();
                std::thread::spawn(move || FaultPlan::trace(&cfg, worker, d, n))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), reference);
        }
    });
}

#[test]
fn prop_actions_respect_the_configuration() {
    // Every drawn action must have positive configured probability, and
    // a delay must hold for exactly `delay_polls` (reorder = one poll).
    check("actions legal", default_cases(), |rng| {
        let cfg = plan(rng);
        let worker = gen::int(rng, 0, 15);
        let d = dir(rng);
        let side = match d {
            Dir::Up => cfg.up,
            Dir::Down => cfg.down,
        };
        for action in FaultPlan::trace(&cfg, worker, d, 512) {
            match action {
                FaultAction::Deliver => {}
                FaultAction::Drop => assert!(side.drop > 0.0, "{side:?}"),
                FaultAction::Duplicate => assert!(side.duplicate > 0.0, "{side:?}"),
                FaultAction::Corrupt => assert!(side.corrupt > 0.0, "{side:?}"),
                FaultAction::Delay(p) => {
                    if p == 1 {
                        assert!(
                            side.reorder > 0.0 || (side.delay > 0.0 && side.delay_polls == 1),
                            "{side:?}"
                        );
                    } else {
                        assert!(
                            side.delay > 0.0 && side.delay_polls == p,
                            "delay({p}) from {side:?}"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_links_draw_from_independent_streams() {
    // Changing the link or the direction reseeds the stream; changing
    // the seed reshuffles every link. (Equality of two independent
    // 256-draw traces is astronomically unlikely for any plan with at
    // least one meaningfully probable fault, so require one.)
    check("links independent", default_cases(), |rng| {
        let mut cfg = plan(rng);
        cfg.up.drop = cfg.up.drop.max(0.3);
        cfg.down.drop = cfg.down.drop.max(0.3);
        // Renormalize so the probabilities still sum to <= 1.
        for side in [&mut cfg.up, &mut cfg.down] {
            let sum = side.drop + side.delay + side.duplicate + side.reorder + side.corrupt;
            if sum > 1.0 {
                side.delay /= sum;
                side.duplicate /= sum;
                side.reorder /= sum;
                side.corrupt /= sum;
                side.drop /= sum;
            }
        }
        let worker = gen::int(rng, 0, 7);
        let a = FaultPlan::trace(&cfg, worker, Dir::Up, 256);
        assert_ne!(
            a,
            FaultPlan::trace(&cfg, worker + 1, Dir::Up, 256),
            "neighbouring links share a stream"
        );
        assert_ne!(
            a,
            FaultPlan::trace(&cfg, worker, Dir::Down, 256),
            "directions share a stream"
        );
        let mut reseeded = cfg.clone();
        reseeded.seed = cfg.seed.wrapping_add(1);
        assert_ne!(
            a,
            FaultPlan::trace(&reseeded, worker, Dir::Up, 256),
            "seed does not reach the stream"
        );
    });
}
