//! Transport-seam conformance: the TCP backend must be indistinguishable
//! from the in-process bus at the protocol layer.
//!
//! * **Frame identity** — the payload a `TcpWorkerLink` puts on the wire
//!   is byte-for-byte the `ser/` encoding the bus carries, and both ends
//!   report the same (payload-only) wire sizes; the 4-byte length prefix
//!   is transport framing and never accounted.
//! * **Outcome parity** — a lockstep cluster run reports the *same*
//!   `ClusterOutcome` over loopback TCP (leader + workers on separate
//!   sockets) as over the in-process bus.
//! * **Hostile frames** — an oversized length prefix is a typed `Decode`
//!   error naming the peer; truncated frames and mid-frame disconnects
//!   surface as `Disconnected` only after queued valid frames drain; a
//!   worker with the wrong config digest is refused at handshake without
//!   wedging cluster formation.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use kdol::config::{
    CompressionConfig, ExperimentConfig, KernelConfig, ProtocolConfig, TransportConfig,
};
use kdol::coordinator::net::{run_cluster_join, run_cluster_listen_on};
use kdol::coordinator::{run_cluster, ClusterOutcome};
use kdol::network::transport::tcp::{
    TcpTransport, TcpWorkerLink, HANDSHAKE_MAGIC, MAX_FRAME_LEN, WIRE_VERSION,
};
use kdol::network::{BusError, Message, Peer, SvBlock, Transport, WorkerLink};
use kdol::ser::{to_bytes, DecodeError};

const DIGEST: u64 = 0xD1_6E57;
const RECV: Duration = Duration::from_secs(10);

/// Perform the leader side of the handshake on a raw accepted socket.
fn raw_accept(listener: &TcpListener) -> TcpStream {
    let (mut stream, _) = listener.accept().unwrap();
    let mut hello = [0u8; 17];
    stream.read_exact(&mut hello).unwrap();
    assert_eq!(&hello[0..4], &HANDSHAKE_MAGIC);
    assert_eq!(hello[4], WIRE_VERSION);
    stream.write_all(&[1]).unwrap();
    stream
}

/// Write one length-prefixed frame on a raw socket.
fn raw_frame(stream: &mut TcpStream, payload: &[u8]) {
    stream.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    stream.write_all(payload).unwrap();
}

/// Connect a raw socket and handshake as `worker` with `digest`.
fn raw_connect(addr: &str, worker: u32, digest: u64) -> TcpStream {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut hello = Vec::with_capacity(17);
    hello.extend_from_slice(&HANDSHAKE_MAGIC);
    hello.push(WIRE_VERSION);
    hello.extend_from_slice(&worker.to_le_bytes());
    hello.extend_from_slice(&digest.to_le_bytes());
    stream.write_all(&hello).unwrap();
    let mut verdict = [0u8; 1];
    stream.read_exact(&mut verdict).unwrap();
    assert_eq!(verdict[0], 1, "handshake refused");
    stream
}

fn sample_messages() -> Vec<Message> {
    vec![
        Message::Violation {
            learner: 2,
            round: 17,
            distance_sq: 0.3125,
        },
        Message::DistanceRequest,
        Message::ModelUpload {
            learner: 1,
            round: 9,
            coeffs: vec![(0, 0.5), (7, -1.25)],
            new_svs: SvBlock {
                ids: vec![7],
                dim: 3,
                coords: vec![1.0, -2.0, 0.5],
            },
        },
        Message::LinearUpload {
            learner: 0,
            round: 4,
            w: vec![0.25, -0.75, 3.5],
        },
        Message::LinearDownload {
            w: vec![1.5, 0.0],
            partial: true,
        },
        Message::Proceed,
        Message::Shutdown,
    ]
}

#[test]
fn tcp_frames_are_byte_identical_to_bus_payloads() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let msgs = sample_messages();

    let sender = {
        let msgs = msgs.clone();
        std::thread::spawn(move || {
            let link = TcpWorkerLink::connect(&addr, 3, DIGEST, Duration::from_secs(5)).unwrap();
            let sizes: Vec<usize> = msgs.iter().map(|m| link.send(m).unwrap()).collect();
            // One frame back from the "coordinator": same payload-only size.
            let (msg, n) = link.recv(RECV).unwrap();
            (sizes, msg, n)
        })
    };

    let mut stream = raw_accept(&listener);
    for msg in &msgs {
        // The canonical frame bytes are exactly what the in-process bus
        // would carry for this message.
        let bus_payload = to_bytes(msg).unwrap();
        let mut hdr = [0u8; 4];
        stream.read_exact(&mut hdr).unwrap();
        assert_eq!(
            u32::from_le_bytes(hdr) as usize,
            bus_payload.len(),
            "length prefix must carry the exact payload size"
        );
        let mut payload = vec![0u8; bus_payload.len()];
        stream.read_exact(&mut payload).unwrap();
        assert_eq!(payload, bus_payload, "TCP payload differs from bus frame");
    }
    let down = Message::SyncRequest;
    let down_payload = to_bytes(&down).unwrap();
    raw_frame(&mut stream, &down_payload);

    let (sizes, got, n) = sender.join().unwrap();
    assert_eq!(got, down, "decoded downstream message");
    assert_eq!(n, down_payload.len(), "recv reports payload-only size");
    for (msg, size) in msgs.iter().zip(sizes) {
        assert_eq!(
            size,
            to_bytes(msg).unwrap().len(),
            "send must report the payload-only size the bus reports"
        );
    }
}

/// Compare every observable field of two cluster outcomes (CommStats has
/// no PartialEq by design — compare field by field).
fn assert_outcomes_equal(a: &ClusterOutcome, b: &ClusterOutcome) {
    assert_eq!(a.cum_loss.to_bits(), b.cum_loss.to_bits(), "cum_loss");
    assert_eq!(a.cum_error.to_bits(), b.cum_error.to_bits(), "cum_error");
    assert_eq!(a.rounds, b.rounds, "rounds");
    assert_eq!(a.comm.up_bytes, b.comm.up_bytes, "up_bytes");
    assert_eq!(a.comm.down_bytes, b.comm.down_bytes, "down_bytes");
    assert_eq!(a.comm.up_msgs, b.comm.up_msgs, "up_msgs");
    assert_eq!(a.comm.down_msgs, b.comm.down_msgs, "down_msgs");
    assert_eq!(a.comm.syncs, b.comm.syncs, "syncs");
    assert_eq!(a.comm.violations, b.comm.violations, "violations");
    assert_eq!(a.comm.last_sync_round, b.comm.last_sync_round, "last_sync_round");
    assert_eq!(a.comm.peak_round_bytes, b.comm.peak_round_bytes, "peak_round_bytes");
    assert_eq!(a.partial_syncs, b.partial_syncs, "partial_syncs");
    assert_eq!(
        a.cum_compression_err.to_bits(),
        b.cum_compression_err.to_bits(),
        "cum_compression_err"
    );
    assert_eq!(a.robustness, b.robustness, "robustness");
    assert_eq!(a.quarantine, b.quarantine, "quarantine");
    // Models carry f64s whose Debug rendering is value-exact; no
    // PartialEq on SvModel, so compare the canonical rendering.
    assert_eq!(
        format!("{:?}", a.final_model),
        format!("{:?}", b.final_model),
        "final_model"
    );
}

/// Run one lockstep config on both backends and require exact agreement.
fn assert_backend_parity(base: &ExperimentConfig) {
    let in_process = run_cluster(base).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let workers: Vec<_> = (0..base.learners)
        .map(|i| {
            let mut wcfg = base.clone();
            wcfg.transport = TransportConfig::Join {
                addr: addr.clone(),
                worker: i,
            };
            std::thread::spawn(move || run_cluster_join(&wcfg))
        })
        .collect();
    let mut lcfg = base.clone();
    lcfg.transport = TransportConfig::Listen { addr };
    let over_tcp = run_cluster_listen_on(&lcfg, listener).unwrap();
    for w in workers {
        w.join().unwrap().unwrap();
    }

    assert_outcomes_equal(&in_process, &over_tcp);
}

#[test]
fn lockstep_linear_outcome_identical_over_tcp() {
    let mut c = ExperimentConfig::quickstart();
    c.name = "tcp-parity-linear".into();
    c.learners = 3;
    c.rounds = 60;
    c.learner.kernel = KernelConfig::Linear;
    c.learner.compression = CompressionConfig::None;
    c.learner.eta = 0.1;
    c.protocol = ProtocolConfig::Dynamic {
        delta: 0.3,
        check_period: 1,
    };
    c.partial_sync = true;
    c.lockstep = true;
    assert_backend_parity(&c);
}

#[test]
fn lockstep_kernel_outcome_identical_over_tcp() {
    // Scheduled kernel protocol: exercises the SvBlock / coeff frames
    // (delta-encoded uploads, union downloads) over real sockets.
    let mut c = ExperimentConfig::quickstart();
    c.name = "tcp-parity-kernel".into();
    c.learners = 2;
    c.rounds = 60;
    c.protocol = ProtocolConfig::Periodic { period: 10 };
    c.lockstep = true;
    assert_backend_parity(&c);
}

#[test]
fn oversized_length_prefix_is_decode_error_naming_the_learner() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut stream = raw_connect(&addr, 0, DIGEST);
        // A valid frame first: it must be delivered before the poison.
        raw_frame(&mut stream, &to_bytes(&Message::DistanceRequest).unwrap());
        // Hostile length prefix far above the cap; no payload follows.
        stream.write_all(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes()).unwrap();
        stream
    });
    let transport = TcpTransport::accept(&listener, 1, DIGEST).unwrap();
    let _stream = client.join().unwrap();

    let (from, msg, _) = transport.recv(RECV).unwrap();
    assert_eq!((from, msg), (0, Message::DistanceRequest));
    match transport.recv(RECV) {
        Err(BusError::Decode {
            from: Peer::Learner(0),
            err: DecodeError::LengthOverflow,
        }) => {}
        other => panic!("want Decode/LengthOverflow from learner 0, got {other:?}"),
    }
    // The poisoned link is dropped; with it gone the transport reports
    // Disconnected, not an infinite timeout loop.
    assert!(matches!(transport.recv(RECV), Err(BusError::Disconnected)));
}

#[test]
fn truncated_frame_surfaces_as_disconnect_after_draining() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let client = std::thread::spawn(move || {
        let mut stream = raw_connect(&addr, 0, DIGEST);
        raw_frame(&mut stream, &to_bytes(&Message::Proceed).unwrap());
        // Announce 64 bytes, deliver 3, vanish mid-frame.
        stream.write_all(&64u32.to_le_bytes()).unwrap();
        stream.write_all(&[1, 2, 3]).unwrap();
        // Drop closes the socket.
    });
    let transport = TcpTransport::accept(&listener, 1, DIGEST).unwrap();
    client.join().unwrap();

    let (from, msg, _) = transport.recv(RECV).unwrap();
    assert_eq!((from, msg), (0, Message::Proceed));
    assert!(matches!(transport.recv(RECV), Err(BusError::Disconnected)));
}

#[test]
fn worker_link_maps_hostility_to_coordinator_provenance() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let link_thread = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let link = TcpWorkerLink::connect(&addr, 0, DIGEST, Duration::from_secs(5)).unwrap();
            let oversized = link.recv(RECV);
            let after = link.recv(RECV);
            (oversized, after)
        })
    };
    let mut stream = raw_accept(&listener);
    stream.write_all(&((MAX_FRAME_LEN as u32) + 7).to_le_bytes()).unwrap();
    let (oversized, after) = link_thread.join().unwrap();
    match oversized {
        Err(BusError::Decode {
            from: Peer::Coordinator,
            err: DecodeError::LengthOverflow,
        }) => {}
        other => panic!("want Decode/LengthOverflow from coordinator, got {other:?}"),
    }
    assert!(matches!(after, Err(BusError::Disconnected)));
    drop(stream);
}

#[test]
fn wrong_digest_is_refused_without_wedging_formation() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let clients = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            // Wrong digest: must be refused at handshake.
            let refused = TcpWorkerLink::connect(&addr, 0, DIGEST ^ 1, Duration::from_secs(5));
            assert!(refused.is_err(), "mismatched config digest admitted");
            // The accept loop must still be alive for the honest worker.
            let link = TcpWorkerLink::connect(&addr, 0, DIGEST, Duration::from_secs(5)).unwrap();
            link.send(&Message::DistanceRequest).unwrap();
            link
        })
    };
    let transport = TcpTransport::accept(&listener, 1, DIGEST).unwrap();
    let (from, msg, _) = transport.recv(RECV).unwrap();
    assert_eq!((from, msg), (0, Message::DistanceRequest));
    drop(clients.join().unwrap());
}
