//! Property tests on the RKHS algebra: Gram identities, Cholesky solves,
//! learner invariants — the native twins of the python hypothesis sweeps.

use kdol::config::{CompressionConfig, KernelConfig, LearnerConfig, LossKind};
use kdol::kernel::gram::{cholesky_solve, Gram};
use kdol::kernel::Kernel;
use kdol::learner::{build_learner, KernelLearner, OnlineLearner};
use kdol::testing::{check, default_cases, gen};
use kdol::util::Rng;

fn rbf(gamma: f64) -> Kernel {
    Kernel::Rbf { gamma }
}

#[test]
fn prop_gram_psd_quadratic_forms() {
    // v^T K v = ||sum_i v_i phi(x_i)||^2 >= 0 for any v.
    check("gram-psd", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 1, 10);
        let pts: Vec<f64> = gen::vector(rng, n * dim, 1.0);
        let g = Gram::compute_symmetric(&rbf(0.7), &pts, dim);
        let v = gen::vector(rng, n, 1.0);
        assert!(g.quad_form(&v, &v) >= -1e-9);
    });
}

#[test]
fn prop_gram_symmetric_consistency() {
    check("gram-sym", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 1, 8);
        let pts: Vec<f64> = gen::vector(rng, n * dim, 1.0);
        let g1 = Gram::compute(&rbf(1.1), &pts, &pts, dim);
        let g2 = Gram::compute_symmetric(&rbf(1.1), &pts, dim);
        for (a, b) in g1.data.iter().zip(&g2.data) {
            assert!((a - b).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_cholesky_solves_spd_systems() {
    check("chol", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let n = gen::int(rng, 1, 8);
        // Distinct points => PD Gram (with a small ridge).
        let pts: Vec<f64> = gen::vector(rng, n * dim, 2.0);
        let g = Gram::compute_symmetric(&rbf(0.5), &pts, dim);
        let b = gen::vector(rng, n, 1.0);
        if let Some(x) = cholesky_solve(&g, &b, 1e-8) {
            // Residual of (K + ridge I) x = b.
            for i in 0..n {
                let mut kx = 1e-8 * x[i];
                for j in 0..n {
                    kx += g.at(i, j) * x[j];
                }
                assert!((kx - b[i]).abs() < 1e-5, "residual {}", (kx - b[i]).abs());
            }
        }
    });
}

#[test]
fn prop_learner_drift_is_exact() {
    // The incremental drift every update reports equals the true RKHS
    // distance between consecutive models — the quantity Prop. 6 sums.
    check("drift-exact", default_cases() / 2, |rng| {
        let cfg = LearnerConfig {
            eta: 0.3 + rng.f64() * 0.4,
            lambda: rng.f64() * 0.05,
            loss: LossKind::Hinge,
            kernel: KernelConfig::Rbf { gamma: 0.5 },
            compression: CompressionConfig::None,
            passive_aggressive: false,
        };
        let dim = gen::int(rng, 1, 3);
        let mut learner = KernelLearner::new(cfg, dim, 0);
        for _ in 0..15 {
            let x = gen::vector(rng, dim, 1.0);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            let before = learner.expansion().clone();
            let ev = learner.update(&x, y);
            let exact = learner.expansion().distance_sq(&before).sqrt();
            assert!(
                (ev.drift - exact).abs() < 1e-7 * exact.max(1.0),
                "drift {} vs exact {}",
                ev.drift,
                exact
            );
        }
    });
}

#[test]
fn prop_learner_drift_bounds() {
    // SGD (lambda = 0, RBF k(x,x) = 1): drift <= eta and 0 at zero loss.
    // PA: exactly loss-proportional — drift <= loss (Prop. 6 premise).
    check("drift-bound", default_cases() / 2, |rng| {
        for loss in [LossKind::Hinge, LossKind::Logistic] {
            let eta = 0.2 + rng.f64() * 0.6;
            for pa in [false, true] {
                let cfg = LearnerConfig {
                    eta: if pa { 1.0 } else { eta },
                    lambda: 0.0,
                    loss,
                    kernel: KernelConfig::Rbf { gamma: 0.5 },
                    compression: CompressionConfig::None,
                    passive_aggressive: pa,
                };
                let dim = gen::int(rng, 1, 3);
                let mut learner = build_learner(&cfg, dim, 0);
                for _ in 0..10 {
                    let x = gen::vector(rng, dim, 1.0);
                    let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
                    let ev = learner.update(&x, y);
                    if pa {
                        assert!(
                            ev.drift <= ev.loss + 1e-9,
                            "{loss:?} PA: drift {} > loss {}",
                            ev.drift,
                            ev.loss
                        );
                    } else {
                        assert!(ev.drift <= eta + 1e-9, "{loss:?}: drift {}", ev.drift);
                        if ev.loss == 0.0 {
                            assert_eq!(ev.drift, 0.0);
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_padding_preserves_predictions() {
    // The XLA padding convention (alpha = 0 slots) is exact, natively.
    use kdol::runtime::pad_expansion;
    check("padding", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 0, 10);
        let model = gen::sv_model(rng, rbf(0.5), n, dim, 99);
        let tau = n + gen::int(rng, 0, 6);
        let (svs, alphas) = pad_expansion(&model, tau).unwrap();
        // Rebuild a model from the padded arrays; predictions must match
        // (up to f32 quantization of the padded representation).
        let mut rebuilt = kdol::kernel::SvModel::new(rbf(0.5), dim);
        for i in 0..tau {
            let x: Vec<f64> = (0..dim).map(|j| svs[i * dim + j] as f64).collect();
            rebuilt.push(i as u64, &x, alphas[i] as f64);
        }
        for _ in 0..3 {
            let q = gen::vector(rng, dim, 1.0);
            assert!(
                (model.predict(&q) - rebuilt.predict(&q)).abs() < 1e-4,
                "padding changed prediction"
            );
        }
    });
}
