//! Property tests on the RKHS algebra: Gram identities, Cholesky solves,
//! learner invariants — the native twins of the python hypothesis sweeps.

use kdol::config::{CompressionConfig, KernelConfig, LearnerConfig, LossKind};
use kdol::kernel::gram::{cholesky_solve, Gram};
use kdol::kernel::Kernel;
use kdol::learner::{build_learner, KernelLearner, OnlineLearner};
use kdol::testing::{check, default_cases, gen};
use kdol::util::Rng;

fn rbf(gamma: f64) -> Kernel {
    Kernel::Rbf { gamma }
}

#[test]
fn prop_gram_psd_quadratic_forms() {
    // v^T K v = ||sum_i v_i phi(x_i)||^2 >= 0 for any v.
    check("gram-psd", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 1, 10);
        let pts: Vec<f64> = gen::vector(rng, n * dim, 1.0);
        let g = Gram::compute_symmetric(&rbf(0.7), &pts, dim);
        let v = gen::vector(rng, n, 1.0);
        assert!(g.quad_form(&v, &v) >= -1e-9);
    });
}

#[test]
fn prop_gram_symmetric_consistency() {
    check("gram-sym", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 1, 8);
        let pts: Vec<f64> = gen::vector(rng, n * dim, 1.0);
        let g1 = Gram::compute(&rbf(1.1), &pts, &pts, dim);
        let g2 = Gram::compute_symmetric(&rbf(1.1), &pts, dim);
        for (a, b) in g1.data.iter().zip(&g2.data) {
            assert!((a - b).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_cholesky_solves_spd_systems() {
    check("chol", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 3);
        let n = gen::int(rng, 1, 8);
        // Distinct points => PD Gram (with a small ridge).
        let pts: Vec<f64> = gen::vector(rng, n * dim, 2.0);
        let g = Gram::compute_symmetric(&rbf(0.5), &pts, dim);
        let b = gen::vector(rng, n, 1.0);
        if let Some(x) = cholesky_solve(&g, &b, 1e-8) {
            // Residual of (K + ridge I) x = b.
            for i in 0..n {
                let mut kx = 1e-8 * x[i];
                for j in 0..n {
                    kx += g.at(i, j) * x[j];
                }
                assert!((kx - b[i]).abs() < 1e-5, "residual {}", (kx - b[i]).abs());
            }
        }
    });
}

#[test]
fn prop_learner_drift_is_exact() {
    // The incremental drift every update reports equals the true RKHS
    // distance between consecutive models — the quantity Prop. 6 sums.
    check("drift-exact", default_cases() / 2, |rng| {
        let cfg = LearnerConfig {
            eta: 0.3 + rng.f64() * 0.4,
            lambda: rng.f64() * 0.05,
            loss: LossKind::Hinge,
            kernel: KernelConfig::Rbf { gamma: 0.5 },
            compression: CompressionConfig::None,
            passive_aggressive: false,
        };
        let dim = gen::int(rng, 1, 3);
        let mut learner = KernelLearner::new(cfg, dim, 0);
        for _ in 0..15 {
            let x = gen::vector(rng, dim, 1.0);
            let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
            let before = learner.expansion().clone();
            let ev = learner.update(&x, y);
            let exact = learner.expansion().distance_sq(&before).sqrt();
            assert!(
                (ev.drift - exact).abs() < 1e-7 * exact.max(1.0),
                "drift {} vs exact {}",
                ev.drift,
                exact
            );
        }
    });
}

#[test]
fn prop_learner_drift_bounds() {
    // SGD (lambda = 0, RBF k(x,x) = 1): drift <= eta and 0 at zero loss.
    // PA: exactly loss-proportional — drift <= loss (Prop. 6 premise).
    check("drift-bound", default_cases() / 2, |rng| {
        for loss in [LossKind::Hinge, LossKind::Logistic] {
            let eta = 0.2 + rng.f64() * 0.6;
            for pa in [false, true] {
                let cfg = LearnerConfig {
                    eta: if pa { 1.0 } else { eta },
                    lambda: 0.0,
                    loss,
                    kernel: KernelConfig::Rbf { gamma: 0.5 },
                    compression: CompressionConfig::None,
                    passive_aggressive: pa,
                };
                let dim = gen::int(rng, 1, 3);
                let mut learner = build_learner(&cfg, dim, 0);
                for _ in 0..10 {
                    let x = gen::vector(rng, dim, 1.0);
                    let y = if rng.chance(0.5) { 1.0 } else { -1.0 };
                    let ev = learner.update(&x, y);
                    if pa {
                        assert!(
                            ev.drift <= ev.loss + 1e-9,
                            "{loss:?} PA: drift {} > loss {}",
                            ev.drift,
                            ev.loss
                        );
                    } else {
                        assert!(ev.drift <= eta + 1e-9, "{loss:?}: drift {}", ev.drift);
                        if ev.loss == 0.0 {
                            assert_eq!(ev.drift, 0.0);
                        }
                    }
                }
            }
        }
    });
}

// ---- naive pairwise oracles live in kdol::testing::naive --------------------

use kdol::testing::naive::{distance_sq as naive_distance_sq, inner as naive_inner};

fn kernels_under_test() -> [Kernel; 3] {
    [
        Kernel::Linear,
        Kernel::Rbf { gamma: 0.6 },
        Kernel::Polynomial { degree: 3, c: 0.7 },
    ]
}

/// |got - want| <= 1e-9 * max(1, |want|, scale) — the acceptance bound for
/// the Gram-backed paths against the naive pairwise implementation.
/// `scale` is the natural magnitude of the computation's inputs (e.g. the
/// norms behind a cancellation-prone distance), so "relative" stays
/// meaningful when the result itself is near zero.
fn assert_rel(got: f64, want: f64, scale: f64, what: &str) {
    let tol = 1e-9 * want.abs().max(scale.abs()).max(1.0);
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, naive {want} (|diff| {} > {tol})",
        (got - want).abs()
    );
}

#[test]
fn prop_gram_backed_inner_and_distance_match_naive() {
    // The blocked dot-product sweeps (predict / inner / norm / distance,
    // incl. distance_sq_with_norms) against the naive nested-eval loops,
    // for all three kernels, to <= 1e-9 relative error.
    check("dot-product-vs-naive", default_cases(), |rng| {
        for kernel in kernels_under_test() {
            let dim = gen::int(rng, 1, 5);
            let n = gen::int(rng, 0, 40);
            let m = gen::int(rng, 0, 40);
            let a = gen::sv_model(rng, kernel, n, dim, 1);
            let b = gen::sv_model(rng, kernel, m, dim, 1000);
            let naa = naive_inner(&a, &a);
            let nbb = naive_inner(&b, &b);
            let dist_scale = naa + nbb; // the terms the distance cancels
            assert_rel(a.inner(&b), naive_inner(&a, &b), dist_scale, "inner");
            assert_rel(a.norm_sq(), naa, naa, "norm_sq");
            assert_rel(
                a.distance_sq(&b),
                naive_distance_sq(&a, &b),
                dist_scale,
                "distance_sq",
            );
            assert_rel(
                a.distance_sq_with_norms(&b, a.norm_sq(), b.norm_sq()),
                naive_distance_sq(&a, &b),
                dist_scale,
                "distance_sq_with_norms",
            );
            let q = gen::vector(rng, dim, 1.0);
            let naive_pred: f64 = (0..a.len())
                .map(|i| a.alpha()[i] * kernel.eval(a.sv(i), &q))
                .sum();
            assert_rel(a.predict(&q), naive_pred, naa.max(0.0).sqrt(), "predict");
        }
    });
}

#[test]
fn prop_union_gram_divergence_matches_naive() {
    // The union-Gram divergence (one deduplicated Gram, quadratic forms)
    // against the naive implementation (Prop. 2 average + naive pairwise
    // distances), with id-sharing across models — both bitwise-identical
    // shared SVs (post-sync) and f32-quantized coordinate variants of the
    // same id (wire copies) — for all three kernels.
    use kdol::kernel::SvModel;
    use kdol::protocol::divergence::kernel_divergence;
    check("union-divergence-vs-naive", default_cases() / 2, |rng| {
        for kernel in kernels_under_test() {
            let dim = gen::int(rng, 1, 4);
            let m = gen::int(rng, 2, 4);
            // Shared pool (as if distributed by an earlier sync).
            let shared = gen::sv_model(rng, kernel, gen::int(rng, 0, 6), dim, 500);
            let models: Vec<SvModel> = (0..m)
                .map(|li| {
                    let mut f =
                        gen::sv_model(rng, kernel, gen::int(rng, 0, 10), dim, 1 + 100 * li as u64);
                    for s in 0..shared.len() {
                        if rng.chance(0.7) {
                            if rng.chance(0.5) {
                                // Exact copy: dedups onto one union row.
                                f.push(shared.ids()[s], shared.sv(s), rng.normal());
                            } else {
                                // f32-quantized wire copy: same id, its own
                                // coordinate-variant row.
                                let qx: Vec<f64> =
                                    shared.sv(s).iter().map(|&v| v as f32 as f64).collect();
                                f.push(shared.ids()[s], &qx, rng.normal());
                            }
                        }
                    }
                    f
                })
                .collect();
            let refs: Vec<&SvModel> = models.iter().collect();

            // Naive oracle: the true mean function (1/m) sum_i f_i held as
            // a flat concatenation (duplicates allowed — evaluation is
            // bilinear, so repeated SVs just sum), then naive pairwise
            // distances. Note this is NOT `SvModel::average`, which
            // conflates same-id coordinate variants by design.
            let mut avg = SvModel::new(kernel, dim);
            for f in &refs {
                for i in 0..f.len() {
                    avg.push(f.ids()[i], f.sv(i), f.alpha()[i] / m as f64);
                }
            }
            let avg_norm = naive_inner(&avg, &avg);
            let mut naive_per = Vec::with_capacity(m);
            let mut scales = Vec::with_capacity(m);
            for f in &refs {
                naive_per.push(naive_distance_sq(f, &avg));
                scales.push(naive_inner(f, f) + avg_norm);
            }
            let naive_delta = naive_per.iter().sum::<f64>() / m as f64;
            let delta_scale = scales.iter().cloned().fold(0.0f64, f64::max);

            let got = kernel_divergence(&refs);
            assert_rel(got.delta, naive_delta, delta_scale, "divergence delta");
            for ((g, w), s) in got.per_learner.iter().zip(&naive_per).zip(&scales) {
                assert_rel(*g, *w, *s, "per-learner distance");
            }
        }
    });
}

#[test]
fn prop_padding_preserves_predictions() {
    // The XLA padding convention (alpha = 0 slots) is exact, natively.
    use kdol::runtime::pad_expansion;
    check("padding", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 4);
        let n = gen::int(rng, 0, 10);
        let model = gen::sv_model(rng, rbf(0.5), n, dim, 99);
        let tau = n + gen::int(rng, 0, 6);
        let (svs, alphas) = pad_expansion(&model, tau).unwrap();
        // Rebuild a model from the padded arrays; predictions must match
        // (up to f32 quantization of the padded representation).
        let mut rebuilt = kdol::kernel::SvModel::new(rbf(0.5), dim);
        for i in 0..tau {
            let x: Vec<f64> = (0..dim).map(|j| svs[i * dim + j] as f64).collect();
            rebuilt.push(i as u64, &x, alphas[i] as f64);
        }
        for _ in 0..3 {
            let q = gen::vector(rng, dim, 1.0);
            assert!(
                (model.predict(&q) - rebuilt.predict(&q)).abs() < 1e-4,
                "padding changed prediction"
            );
        }
    });
}
