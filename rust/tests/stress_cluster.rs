//! ROADMAP stress config: a large threaded-cluster run (m >= 32,
//! T >= 10k, dynamic protocol with the mini-batched check and partial
//! sync enabled) exercising leader queue depth, stale-violation
//! suppression (violations stamped before an adoption race the sync they
//! triggered) and escalation from subset balancing to full syncs under
//! contention.
//!
//! `#[ignore]`d by default — it spawns 32 OS threads and runs ~10^4
//! protocol rounds per worker. Run with:
//!
//! ```sh
//! cargo test --release --test stress_cluster -- --ignored --nocapture
//! ```

use kdol::config::{
    CompressionConfig, DataConfig, ExperimentConfig, KernelConfig, LossKind, ProtocolConfig,
};
use kdol::coordinator::run_cluster;

fn stress_config() -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.name = "stress-m32-t10k".into();
    c.seed = 20260729;
    c.learners = 32;
    c.rounds = 10_000;
    c.data = DataConfig::Susy { noise: 0.08 };
    c.learner.eta = 0.35;
    c.learner.lambda = 1e-3;
    c.learner.loss = LossKind::Hinge;
    c.learner.kernel = KernelConfig::Rbf { gamma: 0.25 };
    // Bounded models keep every message O(tau) — the premise that makes a
    // 32-worker dynamic run tractable (and keeps the leader's union
    // bounded at m * tau).
    c.learner.compression = CompressionConfig::Truncation { tau: 16 };
    // Mini-batched condition checks (§4): violations can queue at the
    // leader between check rounds, exercising the stale-round filter.
    c.protocol = ProtocolConfig::Dynamic {
        delta: 0.5,
        check_period: 4,
    };
    c.partial_sync = true;
    c.record_every = 500;
    c
}

#[test]
#[ignore = "stress: 32 worker threads x 10k rounds; run with --ignored"]
fn stress_dynamic_cluster_m32_t10k() {
    let cfg = stress_config();
    cfg.validate().unwrap();
    let out = run_cluster(&cfg).expect("cluster run completes without deadlock");

    println!(
        "stress outcome: loss {:.1}, violations {}, syncs {}, partial {}, \
         bytes {} (peak round {}), last sync round {:?}",
        out.cum_loss,
        out.comm.violations,
        out.comm.syncs,
        out.partial_syncs,
        out.comm.total_bytes(),
        out.comm.peak_round_bytes,
        out.comm.last_sync_round
    );
    println!(
        "sync-Gram cache: {} hits / {} misses / {} evicted rows; compression eps {:.4}",
        out.sync_cache.hits,
        out.sync_cache.misses,
        out.sync_cache.evicted_rows,
        out.cum_compression_err
    );

    assert_eq!(out.rounds, 10_000);
    assert!(out.cum_loss.is_finite() && out.cum_loss > 0.0);

    // The dynamic protocol must actually have fired under this geometry.
    assert!(out.comm.violations > 0, "no violations at delta=0.5");
    let events = out.comm.syncs + out.partial_syncs;
    assert!(events > 0, "violations never resolved into sync events");
    // Every resolution event is triggered by at least one fresh violation
    // (stale ones are suppressed, they never start an event).
    assert!(
        out.comm.violations >= events,
        "violations {} < events {events}",
        out.comm.violations
    );

    // Accounting invariants under contention: per-event rounds close, so
    // the peak exchange sits below the total in any multi-event run.
    assert!(out.comm.peak_round_bytes > 0);
    if events > 1 {
        assert!(out.comm.peak_round_bytes < out.comm.total_bytes());
    }
    // Sync stamps refer to protocol rounds, not event counts.
    if let Some(last) = out.comm.last_sync_round {
        assert!(last <= out.rounds, "sync stamped past the horizon: {last}");
    }

    // Warm-event reuse: consecutive balancing events share most of their
    // support set, so once more than one balancing event has run the
    // leader's persistent sync-Gram cache must report row hits — the
    // counters are exactly what proves warm events evaluate only
    // O(new SVs * union) kernel entries instead of O(union^2).
    if out.partial_syncs > 1 {
        assert!(
            out.sync_cache.misses > 0,
            "balancing events registered no cache rows: {:?}",
            out.sync_cache
        );
        assert!(
            out.sync_cache.hits > 0,
            "no cross-event cache reuse in {} balancing events: {:?}",
            out.partial_syncs,
            out.sync_cache
        );
    }
}
