//! The paper's novel efficiency criterion, machine-checked end-to-end for
//! every model family: on a drifting stream, a dynamic protocol's
//! cumulative communication must stay within the loss-proportional
//! [`EfficiencyReport`] bound — events `<= eta_c * L / sqrt(Delta)`
//! (Prop. 6, loss form) and bytes `<= events_bound * per_event_cost`
//! (Thm. 7 for kernel expansions, the Cor. 8 fixed-size regime for
//! linear and RFF learners).
//!
//! The learners run passive-aggressive updates, whose step is genuinely
//! loss-proportional: the PA step size is `min(loss / ||phi(x)||^2, C)`,
//! so the model moves by at most `loss / ||phi(x)||`. With standardized
//! streams (||x|| >~ 1 away from a negligible tail; RFF features have
//! ||phi|| ~ 1) the proportionality constant is safely below the
//! `ETA_C = 2` we evaluate the bound with.
//!
//! The bound runs are the *pure* dynamic protocol (`partial_sync` off):
//! Prop. 6's per-event `sqrt(Delta)` drift argument needs every event to
//! reset its violators to distance 0 from the reference, which a full
//! synchronization does and subset balancing deliberately does not (a
//! balanced member restarts anywhere inside the safe zone, so balancing
//! events are not individually loss-bounded). The refinement's byte
//! saving over the full-sync-only protocol, and its exact
//! engine/cluster agreement, are asserted by the parity conformance
//! suite on a tuned drift scenario.

use kdol::config::{
    CompressionConfig, DataConfig, ExperimentConfig, KernelConfig, ProtocolConfig,
};
use kdol::experiments::run_experiment;
use kdol::metrics::EfficiencyReport;

/// Update-magnitude constant `||f - phi(f)|| <= ETA_C * loss` for the PA
/// learners below (see module docs).
const ETA_C: f64 = 2.0;

fn drift_cfg(label: &str, kernel: KernelConfig, delta: f64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.name = format!("loss-prop-{label}");
    c.seed = 13;
    c.learners = 4;
    c.rounds = 200;
    c.data = DataConfig::Hyperplane {
        dim: 8,
        drift: 0.05,
    };
    c.learner.kernel = kernel;
    c.learner.eta = 0.3; // PA cap C
    c.learner.passive_aggressive = true;
    c.learner.compression = match kernel {
        // Budget-bound expansions keep the Thm. 7 message size premise.
        KernelConfig::Rbf { .. } => CompressionConfig::Truncation { tau: 16 },
        _ => CompressionConfig::None,
    };
    c.protocol = ProtocolConfig::Dynamic {
        delta,
        check_period: 1,
    };
    // Pure dynamic protocol — see the module docs for why the bound is
    // asserted without the balancing refinement.
    c.partial_sync = false;
    c
}

#[test]
fn communication_stays_loss_proportional_for_all_model_families() {
    let delta = 0.2;
    let families = [
        ("linear", KernelConfig::Linear),
        (
            "rff",
            KernelConfig::Rff {
                gamma: 0.5,
                dim: 64,
            },
        ),
        ("kernel", KernelConfig::Rbf { gamma: 0.5 }),
    ];
    for (label, kernel) in families {
        let cfg = drift_cfg(label, kernel, delta);
        let outcome = run_experiment(&cfg).unwrap();
        // The drift stream must actually exercise the protocol: no
        // communication at all would make the bound vacuous.
        assert!(
            outcome.comm.syncs + outcome.partial_syncs > 0,
            "{label}: the drift workload never triggered a synchronization"
        );
        assert!(outcome.comm.total_bytes() > 0, "{label}: zero bytes");

        // Message-size parameters: kernel expansions are bounded by the
        // union support size, fixed-size models by their model dimension.
        let (sbar, msg_dim) = match kernel {
            KernelConfig::Rbf { .. } => (
                (outcome.mean_svs as usize + 1) * cfg.learners,
                cfg.data.dim(),
            ),
            KernelConfig::Linear => (0, cfg.data.dim()),
            KernelConfig::Rff { dim, .. } => (0, dim),
        };
        let rep = EfficiencyReport::evaluate(&outcome, ETA_C, delta, sbar, msg_dim, None);

        // The paper's loss-proportionality criterion: the event count is
        // bounded by eta_c * L / sqrt(Delta), and with it the bytes.
        let loss_form = rep
            .checks
            .iter()
            .find(|c| c.name.contains("eta*L"))
            .expect("loss-form Prop6 check missing");
        assert!(
            loss_form.holds(),
            "{label}: events {} exceed the loss-proportional bound {} \
             (loss {})",
            loss_form.measured,
            loss_form.bound,
            outcome.cumulative_loss
        );
        let comm = rep
            .checks
            .iter()
            .find(|c| c.name.contains("comm bound"))
            .expect("communication bound check missing");
        assert!(
            comm.holds(),
            "{label}: bytes {} exceed the loss-proportional communication \
             bound {}",
            comm.measured,
            comm.bound
        );
    }
}

#[test]
fn static_stream_communicates_no_more_than_drifting_one() {
    // The flip side of loss proportionality: on a static (lower-loss)
    // stream the dynamic protocol may not spend *more* communication than
    // on the same stream with concept drift — the budget follows the
    // loss, not a schedule.
    let mut static_cfg = drift_cfg("linear-static", KernelConfig::Linear, 0.5);
    static_cfg.data = DataConfig::Hyperplane {
        dim: 8,
        drift: 0.0,
    };
    let mut drifting_cfg = drift_cfg("linear-drifting", KernelConfig::Linear, 0.5);
    drifting_cfg.data = DataConfig::Hyperplane {
        dim: 8,
        drift: 0.1,
    };
    let s = run_experiment(&static_cfg).unwrap();
    let d = run_experiment(&drifting_cfg).unwrap();
    assert!(
        s.cumulative_loss <= d.cumulative_loss,
        "static loss {} > drifting loss {}",
        s.cumulative_loss,
        d.cumulative_loss
    );
    assert!(
        s.comm.total_bytes() <= d.comm.total_bytes(),
        "static stream communicated more ({} bytes) than the drifting one ({} bytes)",
        s.comm.total_bytes(),
        d.comm.total_bytes()
    );
    // Quiescence is reported against the horizon: the static run's tail
    // must be at least as quiet as the drifting run's.
    assert!(
        s.comm.quiescent_rounds(s.rounds) >= d.comm.quiescent_rounds(d.rounds),
        "static run less quiescent than the drifting one"
    );
}
