//! End-to-end experiment shape tests: small-scale versions of every
//! figure/table in DESIGN.md §4 must exhibit the paper's qualitative
//! structure (who wins, what grows, what saturates).

use kdol::experiments::{fig1, fig2, headline, sweeps};
use kdol::metrics::Outcome;

fn find<'a>(outcomes: &'a [Outcome], pat: &str) -> &'a Outcome {
    outcomes
        .iter()
        .find(|o| o.name.contains(pat))
        .unwrap_or_else(|| panic!("no outcome matching `{pat}`"))
}

/// Error accumulated in the second half of the run — isolates converged
/// behaviour from the (shared) early transient.
fn tail_error(o: &Outcome) -> f64 {
    let half = o.rounds / 2;
    let at_half = o
        .series
        .iter()
        .take_while(|s| s.round <= half)
        .last()
        .map(|s| s.cum_error)
        .unwrap_or(0.0);
    o.cumulative_error - at_half
}

#[test]
fn fig1_shape() {
    let outcomes = fig1::run(&[0.2], 50, 0.3).unwrap();
    let lin_ns = find(&outcomes, "linear-nosync");
    let lin_c = find(&outcomes, "linear-continuous");
    let ker_c = find(&outcomes, "kernel-continuous");
    let ker_d = find(&outcomes, "fig1-kernel-dynamic");
    let ker_t = find(&outcomes, "trunc50");

    // Linear suffers much more error than kernel once past the transient
    // (the hypothesis-class gap Fig 1 is about).
    assert!(
        tail_error(lin_c) > 1.3 * tail_error(ker_c),
        "tail: linear {} vs kernel {}",
        tail_error(lin_c),
        tail_error(ker_c)
    );
    // Continuous kernel sync is the most expensive system by far.
    assert!(ker_c.comm.total_bytes() > 3 * lin_c.comm.total_bytes());
    // Dynamic slashes kernel communication.
    assert!(ker_d.comm.total_bytes() < ker_c.comm.total_bytes() / 2);
    // Compression reduces communication further (or at least not worse).
    assert!(ker_t.comm.total_bytes() <= ker_d.comm.total_bytes());
    // Isolated linear learners communicate nothing.
    assert_eq!(lin_ns.comm.total_bytes(), 0);
}

#[test]
fn fig2_shape() {
    let outcomes = fig2::run(&[1], &[0.5], 0.04).unwrap();
    let lin = find(&outcomes, "linear-periodic(b=1)");
    let ker_p = find(&outcomes, "kernel-periodic(b=1)");
    let ker_d = find(&outcomes, "fig2-kernel-dynamic");
    // Kernel fits the nonlinear stock target better.
    assert!(ker_p.cumulative_error < lin.cumulative_error);
    // Periodic kernel sync with m=32 moves far more bytes than dynamic.
    assert!(ker_d.comm.total_bytes() < ker_p.comm.total_bytes());
}

#[test]
fn headline_directions() {
    let h = headline::run(headline::DEFAULT_DELTA, 0.1).unwrap();
    assert!(h.error_reduction > 1.0, "error reduction {}", h.error_reduction);
    assert!(
        h.comm_reduction_vs_continuous > 2.0,
        "comm reduction {}",
        h.comm_reduction_vs_continuous
    );
}

#[test]
fn delta_sweep_is_monotone_in_comm() {
    let outs = sweeps::sweep_delta(&[0.01, 0.3, 3.0], 0.08).unwrap();
    let bytes: Vec<u64> = outs.iter().map(|o| o.comm.total_bytes()).collect();
    assert!(
        bytes[0] >= bytes[1] && bytes[1] >= bytes[2],
        "comm not monotone in Delta: {bytes:?}"
    );
}

#[test]
fn tau_sweep_controls_model_and_bytes() {
    let outs = sweeps::sweep_tau(&[8, 64], 0.2, 0.08).unwrap();
    assert!(outs[0].mean_svs <= 8.0 + 1e-9);
    assert!(outs[1].mean_svs <= 64.0 + 1e-9);
    // Smaller budget, smaller sync messages (when any syncs happened).
    if outs[0].comm.syncs > 0 && outs[1].comm.syncs > 0 {
        let per0 = outs[0].comm.total_bytes() as f64 / outs[0].comm.syncs as f64;
        let per1 = outs[1].comm.total_bytes() as f64 / outs[1].comm.syncs as f64;
        assert!(per0 <= per1 * 1.2, "per-sync bytes {per0} vs {per1}");
    }
}

#[test]
fn check_period_trades_peak_for_latency() {
    let outs = sweeps::sweep_check_period(&[1, 16], 0.02, 0.08).unwrap();
    // Fewer check rounds => at most as many syncs.
    assert!(outs[1].comm.syncs <= outs[0].comm.syncs);
}

#[test]
fn compression_schemes_both_bound_models() {
    let outs = sweeps::sweep_compression(16, 0.2, 0.08).unwrap();
    for o in &outs {
        assert!(o.mean_svs <= 16.0 + 1e-9, "{}: {}", o.name, o.mean_svs);
    }
}
