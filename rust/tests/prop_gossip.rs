//! Property suite of the gossip subsystem (seeded harness from
//! `kdol::testing`, case count overridable via `KDOL_PROP_CASES`):
//!
//! * the Metropolis–Hastings matrix is bitwise symmetric and doubly
//!   stochastic for every topology family and seed;
//! * one full-attendance diffusion step preserves the network-average
//!   weight vector (pre-quantization) — the consequence of double
//!   stochasticity the whole consensus argument rests on;
//! * topology generation is a pure function of `(kind, n, degree,
//!   seed)`, independent of the parallel-backend thread count — and so
//!   is the whole in-process gossip run.

use kdol::config::{ExperimentConfig, GossipConfig, GossipTopology, ProtocolConfig};
use kdol::coordinator::run_gossip;
use kdol::kernel::LinearModel;
use kdol::protocol::gossip::combine;
use kdol::protocol::Topology;
use kdol::testing::{check, default_cases, gen};
use kdol::util::{Pcg64, Rng};

/// Sample a valid `(kind, n, degree)` triple for one case.
fn arb_shape(rng: &mut Pcg64) -> (GossipTopology, usize, usize) {
    match gen::int(rng, 0, 3) {
        0 => (GossipTopology::Ring, gen::int(rng, 2, 16), 0),
        1 => {
            // Composite n >= 4: sample a grid directly.
            let a = gen::int(rng, 2, 4);
            let b = gen::int(rng, 2, 5);
            (GossipTopology::Torus, a * b, 0)
        }
        2 => {
            // n*k even with 1 <= k < n; k is kept small because the
            // pairing model's acceptance probability collapses for
            // dense regular graphs (rejection would dominate the case).
            let n = gen::int(rng, 4, 12);
            let mut k = gen::int(rng, 1, 4.min(n - 1));
            if n % 2 == 1 && k % 2 == 1 {
                k += 1; // odd n needs even k (handshake lemma)
            }
            (GossipTopology::Regular, n, k)
        }
        _ => (GossipTopology::Complete, gen::int(rng, 2, 10), 0),
    }
}

#[test]
fn metropolis_matrix_is_symmetric_and_doubly_stochastic() {
    check("metropolis-doubly-stochastic", default_cases(), |rng| {
        let (kind, n, degree) = arb_shape(rng);
        let t = Topology::build(kind, n, degree, rng.next_u64()).unwrap();
        let w = t.metropolis_weights();

        // Bitwise symmetry: w_ij and w_ji are the same computation on
        // the same degree pair, so even `==` on floats is exact here.
        for i in 0..n {
            for &(j, wij) in &w[i] {
                let back = w[j]
                    .iter()
                    .find(|&&(jj, _)| jj == i)
                    .unwrap_or_else(|| panic!("edge {i}-{j} not symmetric"))
                    .1;
                assert_eq!(wij.to_bits(), back.to_bits(), "w[{i}][{j}] != w[{j}][{i}]");
                assert!(wij > 0.0 && wij < 1.0);
            }
        }

        // Rows sum to 1 with the implied self-weight; columns follow by
        // symmetry, making the matrix doubly stochastic.
        for i in 0..n {
            let off: f64 = w[i].iter().map(|&(_, v)| v).sum();
            let self_weight = 1.0 - off;
            assert!(
                self_weight > 0.0,
                "{kind:?} n={n}: node {i} self-weight {self_weight} <= 0"
            );
            assert!((off + self_weight - 1.0).abs() < 1e-12);
        }
    });
}

#[test]
fn full_attendance_diffusion_preserves_the_network_average() {
    check("diffusion-preserves-average", default_cases(), |rng| {
        let (kind, n, degree) = arb_shape(rng);
        let t = Topology::build(kind, n, degree, rng.next_u64()).unwrap();
        let w = t.metropolis_weights();
        let dim = gen::int(rng, 1, 8);
        let wires: Vec<Vec<f32>> = (0..n)
            .map(|_| gen::vector(rng, dim, 2.0).iter().map(|&x| x as f32).collect())
            .collect();

        // Pre-step network average (of the f64-widened wire models —
        // the operands every combine actually reduces).
        let mut before = vec![0.0f64; dim];
        for wi in &wires {
            for (a, &x) in before.iter_mut().zip(wi) {
                *a += f64::from(x) / n as f64;
            }
        }

        // One synchronous step: every node combines its closed
        // neighborhood (full attendance) under its Metropolis row.
        let mut after = vec![0.0f64; dim];
        for node in 0..n {
            let mut contribs: Vec<(usize, &[f32])> = t
                .neighbors(node)
                .iter()
                .map(|&j| (j, wires[j].as_slice()))
                .collect();
            contribs.push((node, wires[node].as_slice()));
            contribs.sort_by_key(|&(id, _)| id);
            let combined = combine(node, &w[node], &contribs).unwrap();
            for (a, x) in after.iter_mut().zip(&combined.w) {
                *a += x / n as f64;
            }
        }

        for (b, a) in before.iter().zip(&after) {
            assert!(
                (b - a).abs() < 1e-9,
                "{kind:?} n={n}: average moved {b} -> {a}"
            );
        }
    });
}

#[test]
fn diffusion_step_is_a_convex_contraction_toward_consensus() {
    check("diffusion-contracts-spread", default_cases(), |rng| {
        let (kind, n, degree) = arb_shape(rng);
        let t = Topology::build(kind, n, degree, rng.next_u64()).unwrap();
        let w = t.metropolis_weights();
        let wires: Vec<Vec<f32>> = (0..n)
            .map(|_| gen::vector(rng, 1, 1.0).iter().map(|&x| x as f32).collect())
            .collect();
        let spread = |vals: &[f64]| -> f64 {
            let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        };
        let before: Vec<f64> = wires.iter().map(|v| f64::from(v[0])).collect();
        let mut after = Vec::with_capacity(n);
        for node in 0..n {
            let mut contribs: Vec<(usize, &[f32])> = t
                .neighbors(node)
                .iter()
                .map(|&j| (j, wires[j].as_slice()))
                .collect();
            contribs.push((node, wires[node].as_slice()));
            contribs.sort_by_key(|&(id, _)| id);
            after.push(combine(node, &w[node], &contribs).unwrap().w[0]);
        }
        // A convex combination of neighbors never expands the range.
        assert!(spread(&after) <= spread(&before) + 1e-12);
    });
}

#[test]
fn topology_generation_is_pure_in_seed_n_degree() {
    check("topology-purity", default_cases(), |rng| {
        let (kind, n, degree) = arb_shape(rng);
        let seed = rng.next_u64();
        let a = Topology::build(kind, n, degree, seed).unwrap();
        let b = Topology::build(kind, n, degree, seed).unwrap();
        assert_eq!(a, b, "{kind:?} n={n} degree={degree} seed={seed}");
        // Adjacency invariants (sorted, irreflexive, symmetric,
        // connected) are enforced by `build` itself; spot-check the
        // reported edge count is consistent with the lists.
        let total: usize = (0..n).map(|i| a.degree(i)).sum();
        assert_eq!(a.directed_edges(), total);
    });
}

#[test]
fn topology_and_gossip_run_are_thread_count_invariant() {
    // The parallel backend only affects kernel-algebra throughput; both
    // the sampled graph and the whole in-process run must be bitwise
    // identical at any thread count.
    let shape = (GossipTopology::Regular, 8, 3);
    let reference = Topology::build(shape.0, shape.1, shape.2, 42).unwrap();
    let mut cfg = ExperimentConfig::fig1_linear(ProtocolConfig::NoSync);
    cfg.name = "prop-gossip-threads".into();
    cfg.learners = 4;
    cfg.rounds = 40;
    cfg.record_every = 10;
    cfg.gossip = Some(GossipConfig {
        topology: GossipTopology::Ring,
        degree: 0,
        period: 5,
        seed: 11,
    });

    let mut runs = Vec::new();
    for threads in [1usize, 4] {
        kdol::util::par::set_threads(threads);
        assert_eq!(
            Topology::build(shape.0, shape.1, shape.2, 42).unwrap(),
            reference,
            "graph changed at {threads} threads"
        );
        cfg.threads = threads;
        runs.push(run_gossip(&cfg).unwrap());
    }
    kdol::util::par::set_threads(0);
    assert_eq!(runs[0].final_w, runs[1].final_w);
    assert_eq!(runs[0].comm.total_bytes(), runs[1].comm.total_bytes());
    assert_eq!(runs[0].exchanges, runs[1].exchanges);
}

#[test]
fn quantization_roundtrip_is_exact_on_wire_values() {
    // `from_wire` widens f32 -> f64 exactly, so adopt-then-requantize
    // is the identity — the property that makes "wire model" a
    // well-defined network state.
    check("wire-roundtrip", default_cases(), |rng| {
        let dim = gen::int(rng, 1, 16);
        let w32: Vec<f32> = gen::vector(rng, dim, 3.0).iter().map(|&x| x as f32).collect();
        let round_tripped = LinearModel::from_wire(&w32).to_wire();
        assert_eq!(w32, round_tripped);
    });
}
