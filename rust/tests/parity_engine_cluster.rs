//! Engine ↔ cluster parity: the threaded leader/worker runtime is the
//! deployable twin of the deterministic `ProtocolEngine` and must agree
//! with it.
//!
//! * Scheduled protocols (continuous / periodic, kernel and linear) are
//!   lockstep in both runtimes: sync counts, bytes in each direction,
//!   the recorded sync round, and even the peak-round bytes must match
//!   **exactly**.
//! * Dynamic protocols under free-running workers are violation-driven
//!   and asynchrony shifts which round a violation is observed in, so
//!   only bounded agreement of resolution-event counts (syncs + partial
//!   syncs) is required. The stated tolerance: within a factor of 3 plus
//!   an absolute slack of 3 events, and "no events at all" must agree
//!   exactly (identical trajectories until a first violation exists at
//!   all).
//! * In **lockstep conformance mode** the workers pace protocol rounds
//!   with the leader, so dynamic trajectories are deterministic too: for
//!   fixed-size models (linear and RFF — the engine mirrors the leader's
//!   probe/request accounting for them) the scenario matrix below
//!   asserts **exact** agreement on partial-sync counts, per-direction
//!   bytes/messages, violations, and the last sync round.
//!
//! Also hosts the regression tests for the two cluster accounting fixes:
//! per-event `end_round` (peak bytes < total bytes in any multi-sync
//! run) and round-stamped `record_sync` (quiescence consistent with the
//! protocol horizon).

use kdol::config::{
    CompressionConfig, DataConfig, ExperimentConfig, KernelConfig, ProtocolConfig,
};
use kdol::coordinator::{run_cluster, ClusterOutcome};
use kdol::experiments::run_experiment;
use kdol::metrics::Outcome;

fn cfg(protocol: ProtocolConfig) -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.learners = 3;
    c.rounds = 60;
    c.protocol = protocol;
    c.name = format!("parity-{}", protocol.label());
    c
}

/// Assert exact communication parity between engine and cluster for one
/// scheduled configuration.
fn assert_exact_parity(c: &ExperimentConfig) {
    let engine = run_experiment(c).unwrap();
    let cluster = run_cluster(c).unwrap();
    assert_eq!(engine.comm.syncs, cluster.comm.syncs, "sync counts");
    assert_eq!(engine.comm.up_bytes, cluster.comm.up_bytes, "up bytes");
    assert_eq!(engine.comm.down_bytes, cluster.comm.down_bytes, "down bytes");
    assert_eq!(engine.comm.up_msgs, cluster.comm.up_msgs, "up messages");
    assert_eq!(engine.comm.down_msgs, cluster.comm.down_msgs, "down messages");
    assert_eq!(
        engine.comm.last_sync_round, cluster.comm.last_sync_round,
        "last sync round"
    );
    assert_eq!(
        engine.comm.peak_round_bytes, cluster.comm.peak_round_bytes,
        "peak round bytes"
    );
    assert_eq!(cluster.partial_syncs, 0, "scheduled protocols never balance");
}

#[test]
fn periodic_kernel_parity_is_exact() {
    assert_exact_parity(&cfg(ProtocolConfig::Periodic { period: 10 }));
}

#[test]
fn continuous_kernel_parity_is_exact() {
    assert_exact_parity(&cfg(ProtocolConfig::Continuous));
}

#[test]
fn periodic_linear_parity_is_exact() {
    let mut c = cfg(ProtocolConfig::Periodic { period: 5 });
    c.learner.kernel = KernelConfig::Linear;
    c.learner.compression = CompressionConfig::None;
    assert_exact_parity(&c);
}

#[test]
fn periodic_rff_parity_is_exact() {
    // RFF learners ride the fixed-size sync path: their phi-space weight
    // vector goes over the wire like a linear model.
    let mut c = cfg(ProtocolConfig::Periodic { period: 5 });
    c.learner.kernel = KernelConfig::Rff {
        gamma: 0.5,
        dim: 32,
    };
    c.learner.compression = CompressionConfig::None;
    assert_exact_parity(&c);
}

#[test]
fn lockstep_periodic_kernel_parity_stays_exact() {
    // The lockstep barrier is uncounted runtime control: scheduled
    // protocols must keep their exact parity with it enabled.
    let mut c = cfg(ProtocolConfig::Periodic { period: 10 });
    c.lockstep = true;
    assert_exact_parity(&c);
}

#[test]
fn dynamic_event_counts_agree_within_tolerance() {
    for partial in [false, true] {
        let mut c = cfg(ProtocolConfig::Dynamic {
            delta: 0.5,
            check_period: 1,
        });
        c.learners = 4;
        c.partial_sync = partial;
        let engine = run_experiment(&c).unwrap();
        let cluster = run_cluster(&c).unwrap();
        let engine_events = engine.comm.syncs + engine.partial_syncs;
        let cluster_events = cluster.comm.syncs + cluster.partial_syncs;
        // Stated tolerance for asynchrony: factor 3 + slack 3, and exact
        // agreement on "no events at all".
        assert_eq!(engine_events == 0, cluster_events == 0, "event existence");
        assert!(
            cluster_events <= 3 * engine_events + 3,
            "partial={partial}: cluster {cluster_events} vs engine {engine_events}"
        );
        assert!(
            engine_events <= 3 * cluster_events + 3,
            "partial={partial}: engine {engine_events} vs cluster {cluster_events}"
        );
    }
}

#[test]
fn cluster_partial_sync_resolves_a_violation_without_full_sync() {
    // Acceptance: on a dynamic protocol with partial_sync enabled, the
    // cluster resolves at least one violation by subset balancing. The
    // threshold interacts with the data stream, so sweep a small range of
    // deltas and require balancing to succeed somewhere in it.
    let mut best: Option<(f64, u64)> = None;
    for delta in [0.05, 0.1, 0.2, 0.35, 0.5, 1.0] {
        let mut c = cfg(ProtocolConfig::Dynamic {
            delta,
            check_period: 1,
        });
        c.learners = 4;
        c.rounds = 80;
        c.partial_sync = true;
        let out = run_cluster(&c).unwrap();
        if out.partial_syncs > 0 {
            best = Some((delta, out.partial_syncs));
            break;
        }
    }
    let (delta, partials) = best.expect(
        "no delta in the sweep produced a partial synchronization — \
         subset balancing never resolved a violation",
    );
    assert!(partials > 0, "delta {delta} reported zero partial syncs");
}

#[test]
fn cluster_peak_round_bytes_below_total_in_multi_sync_run() {
    // Regression (accounting fix 2): the leader used to close the
    // accounting round exactly once at shutdown, so the "peak" equalled
    // the total. With per-event rounds, a 6-sync run's peak must sit
    // strictly below its total.
    let out = run_cluster(&cfg(ProtocolConfig::Periodic { period: 10 })).unwrap();
    assert_eq!(out.comm.syncs, 6);
    assert!(out.comm.peak_round_bytes > 0);
    assert!(
        out.comm.peak_round_bytes < out.comm.total_bytes(),
        "peak {} should be < total {}",
        out.comm.peak_round_bytes,
        out.comm.total_bytes()
    );
}

// ---------------------------------------------------------------------------
// Lockstep conformance matrix: dynamic protocols on fixed-size models.
// ---------------------------------------------------------------------------

/// Dynamic drift scenario for a fixed-size model family, lockstep mode.
fn fixed_drift_cfg(label: &str, kernel: KernelConfig, drift: f64, delta: f64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quickstart();
    c.name = format!("conformance-{label}-drift{drift}-delta{delta}");
    c.seed = 7;
    c.learners = 4;
    c.rounds = 100;
    c.data = DataConfig::Hyperplane { dim: 8, drift };
    c.learner.kernel = kernel;
    c.learner.compression = CompressionConfig::None;
    c.learner.eta = 0.1;
    c.protocol = ProtocolConfig::Dynamic {
        delta,
        check_period: 1,
    };
    c.partial_sync = true;
    c.lockstep = true;
    c
}

/// Exact engine ↔ cluster agreement for one (deterministic) dynamic run.
fn assert_lockstep_exact(c: &ExperimentConfig) -> (Outcome, ClusterOutcome) {
    let engine = run_experiment(c).unwrap();
    let cluster = run_cluster(c).unwrap();
    assert_eq!(engine.comm.syncs, cluster.comm.syncs, "{}: syncs", c.name);
    assert_eq!(
        engine.partial_syncs, cluster.partial_syncs,
        "{}: partial syncs",
        c.name
    );
    assert_eq!(
        engine.comm.violations, cluster.comm.violations,
        "{}: violations",
        c.name
    );
    assert_eq!(
        engine.comm.up_bytes, cluster.comm.up_bytes,
        "{}: up bytes",
        c.name
    );
    assert_eq!(
        engine.comm.down_bytes, cluster.comm.down_bytes,
        "{}: down bytes",
        c.name
    );
    assert_eq!(
        engine.comm.up_msgs, cluster.comm.up_msgs,
        "{}: up messages",
        c.name
    );
    assert_eq!(
        engine.comm.down_msgs, cluster.comm.down_msgs,
        "{}: down messages",
        c.name
    );
    assert_eq!(
        engine.comm.last_sync_round, cluster.comm.last_sync_round,
        "{}: last sync round",
        c.name
    );
    assert_eq!(
        engine.comm.peak_round_bytes, cluster.comm.peak_round_bytes,
        "{}: peak round bytes",
        c.name
    );
    // Same models, same rounds: the aggregated losses differ only by
    // floating-point summation order.
    let rel = (engine.cumulative_loss - cluster.cum_loss).abs()
        / engine.cumulative_loss.abs().max(1e-9);
    assert!(
        rel < 1e-9,
        "{}: engine loss {} vs cluster {}",
        c.name,
        engine.cumulative_loss,
        cluster.cum_loss
    );
    (engine, cluster)
}

/// The acceptance scenario, per fixed-size family: some (drift, delta) in
/// the sweep must (a) resolve violations by subset balancing
/// (`partial_syncs > 0`), (b) spend strictly fewer bytes than the
/// full-sync-only protocol on the same seed, and (c) agree with the
/// threaded cluster **exactly** under lockstep.
fn conformance_fixed_family(label: &str, kernel: KernelConfig) {
    let mut chosen: Option<(ExperimentConfig, u64, u64, u64)> = None;
    'search: for &drift in &[0.02, 0.0, 0.05] {
        for &delta in &[0.02, 0.05, 0.1, 0.2, 0.4, 0.8] {
            let c = fixed_drift_cfg(label, kernel, drift, delta);
            let engine = run_experiment(&c).unwrap();
            if engine.partial_syncs == 0 {
                continue;
            }
            // Pre-change baseline: the same scenario with every violation
            // escalating to a full m-worker synchronization.
            let mut full = c.clone();
            full.partial_sync = false;
            full.name = format!("{}-fullsync", full.name);
            let full_engine = run_experiment(&full).unwrap();
            if engine.comm.total_bytes() < full_engine.comm.total_bytes() {
                chosen = Some((
                    c,
                    engine.partial_syncs,
                    engine.comm.total_bytes(),
                    full_engine.comm.total_bytes(),
                ));
                break 'search;
            }
        }
    }
    let (c, partials, partial_bytes, full_bytes) = chosen.unwrap_or_else(|| {
        panic!(
            "{label}: no (drift, delta) in the sweep produced a byte-saving \
             partial synchronization — fixed-size subset balancing never paid off"
        )
    });
    assert!(partials > 0);
    assert!(
        partial_bytes < full_bytes,
        "{label}: partial {partial_bytes} >= full-sync baseline {full_bytes}"
    );
    let (_, cluster) = assert_lockstep_exact(&c);
    assert_eq!(
        cluster.partial_syncs, partials,
        "{label}: cluster must balance exactly as often as the engine"
    );
}

#[test]
fn lockstep_dynamic_linear_parity_is_exact_and_saves_bytes() {
    conformance_fixed_family("linear", KernelConfig::Linear);
}

#[test]
fn lockstep_dynamic_rff_parity_is_exact_and_saves_bytes() {
    conformance_fixed_family(
        "rff",
        KernelConfig::Rff {
            gamma: 0.5,
            dim: 32,
        },
    );
}

#[test]
fn lockstep_dynamic_fixed_escalation_matrix_is_exact() {
    // Even where balancing never succeeds (or never triggers), the
    // lockstep trajectories must agree exactly — escalations, violations
    // and all. Cover both fixed-size families at thresholds bracketing
    // the balancing sweet spot.
    for (label, kernel) in [
        ("linear", KernelConfig::Linear),
        (
            "rff",
            KernelConfig::Rff {
                gamma: 0.5,
                dim: 32,
            },
        ),
    ] {
        for &delta in &[0.01, 0.5] {
            let c = fixed_drift_cfg(label, kernel, 0.05, delta);
            assert_lockstep_exact(&c);
        }
    }
}

#[test]
fn cluster_quiescence_tracks_protocol_rounds() {
    // Regression (accounting fix 1): the leader used to pass the sync
    // *count* to record_sync, so last_sync_round/quiescent_rounds were
    // garbage. With 65 rounds at period 10 the last sync is at round 60:
    // the cluster is quiescent for exactly the 5 trailing rounds.
    let mut c = cfg(ProtocolConfig::Periodic { period: 10 });
    c.rounds = 65;
    let out = run_cluster(&c).unwrap();
    assert_eq!(out.comm.syncs, 6);
    assert_eq!(out.comm.last_sync_round, Some(60));
    assert_eq!(out.comm.quiescent_rounds(out.rounds), 5);
}
