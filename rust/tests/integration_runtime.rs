//! PJRT runtime integration: every XLA artifact must agree with the
//! native RKHS math on the same padded inputs. Requires `make artifacts`;
//! every test is skipped (with a loud message) when artifacts are absent
//! so `cargo test` works on a fresh checkout.

use kdol::kernel::{Kernel, SvModel};
use kdol::protocol::divergence::kernel_divergence;
use kdol::runtime::{pad_expansion, XlaRuntime};
use kdol::util::{Pcg64, Rng};

fn runtime() -> Option<XlaRuntime> {
    let dir = XlaRuntime::default_dir();
    if !dir.join("manifest.toml").exists() {
        eprintln!("SKIP: no artifacts in {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(XlaRuntime::load(&dir, "susy").expect("artifacts load"))
}

static NEXT_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Random expansion with globally unique SV ids (the system invariant —
/// ids are minted per learner via `make_sv_id`; reusing them across models
/// would make the id-merging average incorrect).
fn random_model(rng: &mut Pcg64, n: usize, d: usize, gamma: f64) -> SvModel {
    let mut m = SvModel::new(Kernel::Rbf { gamma }, d);
    for _ in 0..n {
        let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let id = NEXT_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        m.push(id, &x, rng.normal());
    }
    m
}

#[test]
fn xla_predict_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = rt.spec("predict").unwrap().clone();
    let gamma = 0.25f64;
    let mut rng = Pcg64::seeded(11);
    for n in [0, 1, spec.tau / 2, spec.tau] {
        let model = random_model(&mut rng, n, spec.d, gamma);
        let (svs, alphas) = pad_expansion(&model, spec.tau).unwrap();
        let queries: Vec<Vec<f64>> = (0..spec.batch)
            .map(|_| (0..spec.d).map(|_| rng.normal()).collect())
            .collect();
        let mut flat = Vec::new();
        for q in &queries {
            flat.extend(q.iter().map(|&v| v as f32));
        }
        let got = rt.predict(&svs, &alphas, &flat, gamma as f32).unwrap();
        for (q, g) in queries.iter().zip(&got) {
            let want = model.predict(q);
            assert!(
                (want - *g as f64).abs() < 1e-3,
                "n={n}: native {want} vs xla {g}"
            );
        }
    }
}

#[test]
fn xla_gram_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = rt.spec("gram").unwrap().clone();
    let gamma = 0.4f64;
    let mut rng = Pcg64::seeded(12);
    let a = random_model(&mut rng, spec.tau, spec.d, gamma);
    let b = random_model(&mut rng, spec.tau, spec.d, gamma);
    let (fa, _) = pad_expansion(&a, spec.tau).unwrap();
    let (fb, _) = pad_expansion(&b, spec.tau).unwrap();
    let k = rt.gram(&fa, &fb, gamma as f32).unwrap();
    let kern = Kernel::Rbf { gamma };
    for i in 0..spec.tau {
        for j in 0..spec.tau {
            let want = kern.eval(a.sv(i), b.sv(j));
            let got = k[i * spec.tau + j] as f64;
            assert!(
                (want - got).abs() < 1e-4,
                "K[{i},{j}]: native {want} vs xla {got}"
            );
        }
    }
}

#[test]
fn xla_norm_diff_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = rt.spec("norm_diff").unwrap().clone();
    let gamma = 0.3f64;
    let mut rng = Pcg64::seeded(13);
    let f = random_model(&mut rng, spec.tau / 2, spec.d, gamma);
    let r = random_model(&mut rng, spec.tau / 3, spec.d, gamma);
    let (sf, af) = pad_expansion(&f, spec.tau).unwrap();
    let (sr, ar) = pad_expansion(&r, spec.tau).unwrap();
    let got = rt.norm_diff(&sf, &af, &sr, &ar, gamma as f32).unwrap() as f64;
    let want = f.distance_sq(&r);
    assert!(
        (want - got).abs() < 1e-3 * want.max(1.0),
        "native {want} vs xla {got}"
    );
    // Identical models -> ~0.
    let got0 = rt.norm_diff(&sf, &af, &sf, &af, gamma as f32).unwrap();
    assert!(got0.abs() < 1e-3, "self distance {got0}");
}

#[test]
fn xla_divergence_matches_native() {
    let Some(rt) = runtime() else { return };
    let spec = rt.spec("divergence").unwrap().clone();
    let gamma = 0.25f64;
    let mut rng = Pcg64::seeded(14);
    let models: Vec<SvModel> = (0..spec.m)
        .map(|_| random_model(&mut rng, spec.tau / 2, spec.d, gamma))
        .collect();
    let mut svs = Vec::new();
    let mut alphas = Vec::new();
    for m in &models {
        let (s, a) = pad_expansion(m, spec.tau).unwrap();
        svs.extend(s);
        alphas.extend(a);
    }
    let (delta, dists) = rt.divergence(&svs, &alphas, gamma as f32).unwrap();
    let refs: Vec<&SvModel> = models.iter().collect();
    let want = kernel_divergence(&refs);
    assert!(
        (want.delta - delta as f64).abs() < 1e-2 * want.delta.max(1.0),
        "native {} vs xla {}",
        want.delta,
        delta
    );
    for (w, g) in want.per_learner.iter().zip(&dists) {
        assert!((w - *g as f64).abs() < 2e-2 * w.max(1.0), "{w} vs {g}");
    }
}

#[test]
fn xla_rff_predict_executes() {
    let Some(rt) = runtime() else { return };
    let spec = rt.spec("rff_predict").unwrap().clone();
    let mut rng = Pcg64::seeded(15);
    let wvec: Vec<f32> = (0..spec.rff_dim).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..spec.batch * spec.d).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..spec.rff_dim * spec.d).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..spec.rff_dim)
        .map(|_| (rng.f64() * std::f64::consts::TAU) as f32)
        .collect();
    let y = rt.rff_predict(&wvec, &x, &w, &b).unwrap();
    assert_eq!(y.len(), spec.batch);
    assert!(y.iter().all(|v| v.is_finite()));
}

#[test]
fn service_xla_path_agrees_with_native() {
    let Some(rt) = runtime() else { return };
    use kdol::coordinator::{PredictionService, ScorePath};
    let spec = rt.spec("predict").unwrap().clone();
    let gamma = 0.25;
    let mut rng = Pcg64::seeded(16);
    let model = random_model(&mut rng, spec.tau / 2, spec.d, gamma);
    let native = model.clone();
    let mut svc = PredictionService::new(Some(rt), model, gamma).unwrap();
    let queries: Vec<Vec<f64>> = (0..spec.batch)
        .map(|_| (0..spec.d).map(|_| rng.normal()).collect())
        .collect();
    let (scores, path) = svc.score_batch(&queries).unwrap();
    assert_eq!(path, ScorePath::Xla);
    for (q, s) in queries.iter().zip(&scores) {
        assert!((native.predict(q) - s).abs() < 1e-3);
    }
}
