//! Integration tests over the protocol engine: cross-protocol invariants
//! on identical input streams, and the qualitative claims of the paper's
//! figures at reduced scale.

use kdol::config::{CompressionConfig, ExperimentConfig, KernelConfig, ProtocolConfig};
use kdol::experiments::{run_experiment, run_serial};
use kdol::protocol::ProtocolEngine;

fn base() -> ExperimentConfig {
    let mut c = ExperimentConfig::fig1_kernel(ProtocolConfig::Continuous);
    c.learners = 4;
    c.rounds = 150;
    c
}

fn with_protocol(p: ProtocolConfig) -> ExperimentConfig {
    let mut c = base();
    c.protocol = p;
    c.name = format!("it-{}", p.label());
    c
}

#[test]
fn identical_streams_across_protocols() {
    // Same seed => byte-identical inputs => nosync cumulative loss is a
    // pure function of the seed. Run twice to pin determinism end-to-end.
    let a = run_experiment(&with_protocol(ProtocolConfig::NoSync)).unwrap();
    let b = run_experiment(&with_protocol(ProtocolConfig::NoSync)).unwrap();
    assert_eq!(a.cumulative_loss, b.cumulative_loss);
    assert_eq!(a.cumulative_error, b.cumulative_error);
}

#[test]
fn communication_ordering_continuous_periodic_dynamic_nosync() {
    let cont = run_experiment(&with_protocol(ProtocolConfig::Continuous)).unwrap();
    let peri = run_experiment(&with_protocol(ProtocolConfig::Periodic { period: 10 })).unwrap();
    let dyna = run_experiment(&with_protocol(ProtocolConfig::Dynamic {
        delta: 0.5,
        check_period: 1,
    }))
    .unwrap();
    let none = run_experiment(&with_protocol(ProtocolConfig::NoSync)).unwrap();
    assert!(cont.comm.total_bytes() > peri.comm.total_bytes());
    assert!(peri.comm.total_bytes() > 0);
    assert!(dyna.comm.total_bytes() < cont.comm.total_bytes());
    assert_eq!(none.comm.total_bytes(), 0);
}

#[test]
fn synchronization_helps_accuracy() {
    // Averaging m learners' models should beat isolated learners on this
    // kernel-friendly task (the premise of distributed learning).
    let cont = run_experiment(&with_protocol(ProtocolConfig::Continuous)).unwrap();
    let none = run_experiment(&with_protocol(ProtocolConfig::NoSync)).unwrap();
    assert!(
        cont.cumulative_error <= none.cumulative_error * 1.10,
        "continuous {} vs isolated {}",
        cont.cumulative_error,
        none.cumulative_error
    );
}

#[test]
fn dynamic_interpolates_loss_between_extremes() {
    let cont = run_experiment(&with_protocol(ProtocolConfig::Continuous)).unwrap();
    let dyna = run_experiment(&with_protocol(ProtocolConfig::Dynamic {
        delta: 0.2,
        check_period: 1,
    }))
    .unwrap();
    // Dynamic must not be wildly worse than continuous on loss...
    assert!(dyna.cumulative_loss < 2.0 * cont.cumulative_loss + 20.0);
    // ...while communicating less (the margin is modest at this horizon:
    // the early transient keeps local conditions firing — see fig1/fig2
    // shape tests for the post-transient factors).
    assert!(
        dyna.comm.total_bytes() < cont.comm.total_bytes() * 4 / 5,
        "dynamic {} vs continuous {}",
        dyna.comm.total_bytes(),
        cont.comm.total_bytes()
    );
}

#[test]
fn tighter_threshold_means_more_communication() {
    let tight = run_experiment(&with_protocol(ProtocolConfig::Dynamic {
        delta: 0.01,
        check_period: 1,
    }))
    .unwrap();
    let loose = run_experiment(&with_protocol(ProtocolConfig::Dynamic {
        delta: 1.0,
        check_period: 1,
    }))
    .unwrap();
    assert!(tight.comm.syncs >= loose.comm.syncs);
    assert!(tight.comm.total_bytes() >= loose.comm.total_bytes());
}

#[test]
fn check_period_bounds_sync_rate() {
    // With checks every b rounds, syncs <= rounds / b (the §4 peak bound).
    let b = 8usize;
    let o = run_experiment(&with_protocol(ProtocolConfig::Dynamic {
        delta: 0.001, // essentially always violated
        check_period: b,
    }))
    .unwrap();
    assert!(
        o.comm.syncs <= (o.rounds / b as u64) + 1,
        "syncs {} exceed rounds/b {}",
        o.comm.syncs,
        o.rounds / b as u64
    );
}

#[test]
fn compression_caps_message_growth() {
    let mut uncomp = with_protocol(ProtocolConfig::Continuous);
    uncomp.rounds = 120;
    let mut comp = uncomp.clone();
    comp.learner.compression = CompressionConfig::Truncation { tau: 20 };
    comp.name = "it-compressed".into();
    let o_un = run_experiment(&uncomp).unwrap();
    let o_c = run_experiment(&comp).unwrap();
    // Bounded models => strictly less communication than unbounded ones.
    assert!(o_c.comm.total_bytes() < o_un.comm.total_bytes());
    assert!(o_c.mean_svs <= 20.0 + 1e-9);
}

#[test]
fn serial_oracle_and_consistency_direction() {
    let cfg = with_protocol(ProtocolConfig::Continuous);
    let serial = run_serial(&cfg);
    let cont = run_experiment(&cfg).unwrap();
    // Finite-sample consistency: distributed loss within a constant factor
    // of serial loss on the same mT examples.
    let ratio = cont.cumulative_loss / serial.cumulative_loss.max(1e-9);
    assert!(ratio < 4.0, "consistency ratio {ratio}");
}

#[test]
fn linear_protocol_stack_works_end_to_end() {
    let mut cfg = with_protocol(ProtocolConfig::Dynamic {
        delta: 0.05,
        check_period: 1,
    });
    cfg.learner.kernel = KernelConfig::Linear;
    cfg.learner.compression = CompressionConfig::None;
    cfg.learner.eta = 0.05;
    let o = run_experiment(&cfg).unwrap();
    assert!(o.cumulative_loss > 0.0);
    // Linear messages are fixed-size: bytes/sync bounded by
    // m * (upload + download) with d = 18 floats (+ violations/requests).
    if o.comm.syncs > 0 {
        let per_sync = o.comm.total_bytes() as f64 / o.comm.syncs as f64;
        let d_bytes = 18 * 4;
        let upper = (cfg.learners * (2 * d_bytes + 64)) as f64 + 64.0;
        assert!(per_sync <= upper, "per-sync {per_sync} > {upper}");
    }
}

#[test]
fn engine_records_divergence_when_asked() {
    let mut e =
        ProtocolEngine::new(with_protocol(ProtocolConfig::Periodic { period: 25 })).unwrap();
    e.record_divergence = true;
    for _ in 0..100 {
        e.step().unwrap();
    }
    assert_eq!(e.sync_divergences.len(), 4);
    for (_, d) in &e.sync_divergences {
        assert!(*d >= 0.0);
    }
}

#[test]
fn quiescence_on_learnable_stationary_task() {
    // On a margin-separable task with lambda = 0 (no perpetual decay
    // drift) the learners eventually suffer zero hinge loss, updates stop,
    // and the dynamic protocol goes quiescent — the paper's central
    // behavioural claim (communication vanishes as loss approaches zero).
    let mut cfg = with_protocol(ProtocolConfig::Dynamic {
        delta: 0.8,
        check_period: 1,
    });
    cfg.data = kdol::config::DataConfig::Mixture {
        dim: 2,
        separation: 4.0,
    };
    cfg.learners = 3;
    cfg.rounds = 700;
    cfg.learner.lambda = 0.0;
    cfg.learner.eta = 0.5;
    cfg.learner.kernel = kdol::config::KernelConfig::Rbf { gamma: 0.5 };
    let o = run_experiment(&cfg).unwrap();
    match o.quiescent_since() {
        None => {} // never needed to sync at all: quiescent from the start
        Some(last) => assert!(last < 600, "still syncing at round {last} of {}", o.rounds),
    }
    // And communication indeed stopped: quiescent for >= 100 rounds.
    assert!(o.comm.quiescent_rounds(o.rounds) >= 100);
}
