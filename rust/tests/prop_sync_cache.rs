//! Property suites for PR 3's two performance layers:
//!
//! 1. The persistent cross-event [`SyncGramCache`] must be **bitwise**
//!    indistinguishable from a fresh per-event [`UnionGram`] across a
//!    randomized multi-event sequence with shared ids, f32 wire
//!    round-trips, model drift, and store-driven evictions — for pairwise
//!    distances, safe-zone-style average-vs-reference distances, and the
//!    Eq. 1 divergence.
//! 2. The deterministic scoped-thread parallel backend must produce
//!    **bitwise** identical Gram matrices, batched predictions and
//!    exponentials at every thread count 1..8 (it partitions by disjoint
//!    output rows and never reassociates a sum across threads).

use std::collections::HashSet;

use kdol::kernel::{Gram, Kernel, SvModel, SyncGramCache, UnionGram};
use kdol::protocol::divergence::{kernel_divergence, kernel_divergence_cached};
use kdol::util::{par, Pcg64, Rng};

fn random_point(rng: &mut Pcg64, dim: usize) -> Vec<f64> {
    (0..dim).map(|_| rng.normal()).collect()
}

/// One randomized multi-event protocol-shaped workload: models drift
/// between events (new SVs, prunes, shared-id adoptions with f32 wire
/// round-trips), each event computes every sync-time quantity on both the
/// persistent cache and a fresh union, and ids dead in all models are
/// evicted between events like the delta-decoder store would.
#[test]
fn cache_matches_fresh_union_bitwise_across_random_events() {
    let kernel = Kernel::Rbf { gamma: 0.6 };
    let dim = 3;
    let mut rng = Pcg64::seeded(20260729);
    let mut cache = SyncGramCache::new(kernel, dim);
    let m = 4;
    let mut models: Vec<SvModel> = (0..m).map(|_| SvModel::new(kernel, dim)).collect();
    // A slowly-changing shared reference model (the safe-zone check's r).
    let mut reference = SvModel::new(kernel, dim);
    let mut next_id = 1u64;
    let mut all_ids: Vec<u64> = Vec::new();
    let mut saw_eviction = false;

    for event in 0..40 {
        // --- drift between events ----------------------------------------
        for mi in 0..m {
            for _ in 0..rng.below(3) {
                let x = random_point(&mut rng, dim);
                models[mi].push(next_id, &x, rng.normal());
                all_ids.push(next_id);
                next_id += 1;
            }
            // Adopt a peer's SV under the same id: sometimes the exact f64
            // coordinates (post-sync copy), sometimes the f32-quantized
            // wire variant (must occupy its own cache row).
            let peer = (mi + 1) % m;
            if rng.chance(0.6) && !models[peer].is_empty() {
                let j = rng.below(models[peer].len() as u64) as usize;
                let id = models[peer].ids()[j];
                if !models[mi].ids().contains(&id) {
                    let x: Vec<f64> = if rng.chance(0.5) {
                        models[peer].sv(j).to_vec()
                    } else {
                        models[peer].sv(j).iter().map(|&v| v as f32 as f64).collect()
                    };
                    models[mi].push(id, &x, rng.normal());
                }
            }
            // Prune the oldest SV now and then (kills its id eventually).
            if models[mi].len() > 5 {
                models[mi].remove_ordered(0);
            }
        }
        if event % 7 == 3 && !models[0].is_empty() {
            // Refresh the reference from model 0 (bitwise copies).
            reference = models[0].clone();
        }

        // --- the event: cache vs fresh union, same registration order ----
        let mut fresh = UnionGram::new(kernel, dim);
        cache.begin_event();
        let fresh_ref_rows = fresh.add_model(&reference);
        let cache_ref_rows = cache.add_model(&reference);
        for f in &models {
            fresh.add_model(f);
            cache.add_model(f);
        }

        // Pairwise distances between all model pairs.
        for a in 0..m {
            for b in 0..m {
                let fa = fresh.try_coeffs(&models[a]).expect("registered");
                let fb = fresh.try_coeffs(&models[b]).expect("registered");
                let ca = cache.try_coeffs(&models[a]).expect("registered");
                let cb = cache.try_coeffs(&models[b]).expect("registered");
                let want = fresh.distance_sq(&fa, &fb);
                let got = cache.distance_sq(&ca, &cb);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "event {event}: distance({a},{b}) {want} vs {got}"
                );
            }
        }

        // Safe-zone shape: ||avg_B - r||^2 with r scattered sparsely (the
        // engines scatter the reference coefficients onto its rows).
        let sel: Vec<usize> = (0..m).filter(|&i| i % 2 == event % 2 || i == 0).collect();
        let subset: Vec<&SvModel> = sel.iter().map(|&i| &models[i]).collect();
        let avg = SvModel::average(&subset);
        if let (Some(fa), Some(ca)) = (fresh.try_coeffs(&avg), cache.try_coeffs(&avg)) {
            let mut fr = vec![0.0; fresh.len()];
            fresh.scatter(&fresh_ref_rows, reference.alpha(), &mut fr);
            let mut cr = vec![0.0; cache.event_len()];
            cache.scatter(&cache_ref_rows, reference.alpha(), &mut cr);
            let want = fresh.distance_sq(&fa, &fr);
            let got = cache.distance_sq(&ca, &cr);
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "event {event}: safe-zone {want} vs {got}"
            );
        }

        // Divergence (Eq. 1) through the cache == fresh, bitwise.
        let refs: Vec<&SvModel> = models.iter().collect();
        let want = kernel_divergence(&refs);
        let got = kernel_divergence_cached(&mut cache, &refs);
        assert_eq!(want.delta.to_bits(), got.delta.to_bits(), "event {event}");
        for (w, g) in want.per_learner.iter().zip(&got.per_learner) {
            assert_eq!(w.to_bits(), g.to_bits(), "event {event}");
        }

        // --- event boundary: evict ids dead in every model + reference ---
        let live: HashSet<u64> = models
            .iter()
            .flat_map(|f| f.ids().iter().copied())
            .chain(reference.ids().iter().copied())
            .collect();
        let dead: Vec<u64> = all_ids.iter().copied().filter(|id| !live.contains(id)).collect();
        if !dead.is_empty() {
            let before = cache.stats().evicted_rows;
            cache.evict_ids(&dead);
            saw_eviction |= cache.stats().evicted_rows > before;
        }
        all_ids.retain(|id| live.contains(id));
    }

    let stats = cache.stats();
    assert!(stats.hits > 0, "no cross-event reuse observed: {stats:?}");
    assert!(stats.misses > 0, "{stats:?}");
    assert!(saw_eviction, "the sequence never exercised eviction");
    assert!(
        stats.hits > stats.misses,
        "consecutive events share most of their support set, so hits should \
         dominate: {stats:?}"
    );
}

/// Every parallel sweep must equal its serial twin bitwise at any thread
/// count — the backend partitions by disjoint output rows and each entry
/// runs the identical serial arithmetic.
#[test]
fn parallel_backend_is_bitwise_serial_at_any_thread_count() {
    let mut rng = Pcg64::seeded(42);
    let dim = 6;
    // Large enough that the parallel paths actually engage
    // (rows * cols >= PAR_MIN_ELEMS).
    let rows = 160;
    let cols = 130;
    let a: Vec<f64> = (0..rows * dim).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..cols * dim).map(|_| rng.normal()).collect();
    let sym_n = 200;
    let s: Vec<f64> = (0..sym_n * dim).map(|_| rng.normal()).collect();

    let mut model = SvModel::new(Kernel::Rbf { gamma: 0.3 }, dim);
    for i in 0..600u64 {
        let x = random_point(&mut rng, dim);
        model.push(i, &x, rng.normal());
    }
    let queries: Vec<Vec<f64>> = (0..48).map(|_| random_point(&mut rng, dim)).collect();

    let exps: Vec<f64> = (0..40_000).map(|_| -rng.f64() * 30.0).collect();

    for kernel in [
        Kernel::Rbf { gamma: 0.4 },
        Kernel::Linear,
        Kernel::Polynomial { degree: 2, c: 0.5 },
    ] {
        par::set_threads(1);
        let base = Gram::compute(&kernel, &a, &b, dim);
        let base_sym = Gram::compute_symmetric(&kernel, &s, dim);
        for t in 2..=8 {
            par::set_threads(t);
            let g = Gram::compute(&kernel, &a, &b, dim);
            assert_eq!(g.data.len(), base.data.len());
            for (i, (x, y)) in base.data.iter().zip(&g.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{kernel:?} t={t} entry {i}");
            }
            let g = Gram::compute_symmetric(&kernel, &s, dim);
            for (i, (x, y)) in base_sym.data.iter().zip(&g.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{kernel:?} sym t={t} entry {i}");
            }
        }
    }

    // predict_batch: block-contribution order per query is fixed, so the
    // query partition cannot change a single bit.
    par::set_threads(1);
    let base = model.predict_batch(&queries);
    for t in 2..=8 {
        par::set_threads(t);
        let got = model.predict_batch(&queries);
        for (i, (x, y)) in base.iter().zip(&got).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "predict_batch t={t} query {i}");
        }
    }

    // exp_slice over a large buffer (elementwise — trivially partitionable,
    // but pin it anyway).
    par::set_threads(1);
    let mut serial = exps.clone();
    kdol::util::float::exp_slice(&mut serial);
    for t in 2..=8 {
        par::set_threads(t);
        let mut v = exps.clone();
        kdol::util::float::exp_slice(&mut v);
        assert!(serial.iter().zip(&v).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    // Union/cache incremental extension under threads: grow a cache in two
    // steps at each thread count and compare against one-shot serial.
    par::set_threads(1);
    let big_a = {
        let mut f = SvModel::new(Kernel::Rbf { gamma: 0.3 }, dim);
        for i in 0..120u64 {
            let x = random_point(&mut rng, dim);
            f.push(1_000 + i, &x, rng.normal());
        }
        f
    };
    let big_b = {
        let mut f = SvModel::new(Kernel::Rbf { gamma: 0.3 }, dim);
        for i in 0..120u64 {
            let x = random_point(&mut rng, dim);
            f.push(2_000 + i, &x, rng.normal());
        }
        f
    };
    let mut serial_union = UnionGram::new(big_a.kernel, dim);
    serial_union.add_model(&big_a);
    serial_union.add_model(&big_b);
    let ua = serial_union.try_coeffs(&big_a).unwrap();
    let ub = serial_union.try_coeffs(&big_b).unwrap();
    let want = serial_union.distance_sq(&ua, &ub);
    for t in 2..=8 {
        par::set_threads(t);
        let mut cache = SyncGramCache::new(big_a.kernel, dim);
        cache.begin_event();
        cache.add_model(&big_a);
        let ca = cache.try_coeffs(&big_a).unwrap();
        let _ = cache.quad_form(&ca, &ca); // force a first (partial) build
        cache.add_model(&big_b); // then a threaded incremental extension
        let ca = cache.try_coeffs(&big_a).unwrap();
        let cb = cache.try_coeffs(&big_b).unwrap();
        let got = cache.distance_sq(&ca, &cb);
        assert_eq!(want.to_bits(), got.to_bits(), "incremental extension t={t}");
    }
    par::set_threads(0);
}
